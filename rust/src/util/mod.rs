//! In-crate utilities replacing external dependencies (offline build):
//! a minimal JSON parser ([`json`]), a tiny CLI argument helper
//! ([`cli`]), a seeded property-testing loop ([`prop`]), and shared
//! result arithmetic ([`improvement_pct`], [`percentile`]).

pub mod cli;
pub mod json;
pub mod prop;

/// Nearest-rank percentile of a sample: the smallest value such that
/// at least `p`% of the (finite) sample is ≤ it — the load-harness
/// latency statistic (p50/p99), chosen over interpolation because a
/// reported p99 should be a latency that actually occurred.
///
/// Guards, not panics: non-finite entries are ignored, and an empty or
/// all-NaN sample yields `NaN` ("unknown", rendered as `-`), matching
/// the [`improvement_pct`] convention.  `p` is clamped to `[0, 100]`.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut finite: Vec<f64> = xs.iter().copied().filter(|v| v.is_finite()).collect();
    if finite.is_empty() {
        return f64::NAN;
    }
    finite.sort_by(f64::total_cmp);
    let p = p.clamp(0.0, 100.0);
    // Nearest rank: ⌈p/100 · n⌉, 1-based; p = 0 maps to the minimum.
    let rank = ((p / 100.0) * finite.len() as f64).ceil() as usize;
    finite[rank.max(1) - 1]
}

/// The paper's improvement metric, `(reference / candidate − 1) · 100`,
/// NaN-guarded: a non-finite operand or a zero/negative candidate time
/// (instant profiles, failed rows) yields `NaN` — "unknown", for the
/// caller to render as `-` — never an `inf`/`NaN` walked into a table
/// as if it were a number.  One rule shared by the fig9 driver, the
/// corpus sweep/tuner, and the service demo, so every improvement
/// column in the repo agrees on its edge cases.
pub fn improvement_pct(reference_ms: f64, candidate_ms: f64) -> f64 {
    if reference_ms.is_finite() && candidate_ms.is_finite() && candidate_ms > 0.0 {
        (reference_ms / candidate_ms - 1.0) * 100.0
    } else {
        f64::NAN
    }
}

#[cfg(test)]
mod tests {
    use super::{improvement_pct, percentile};

    #[test]
    fn percentile_nearest_rank_on_known_vectors() {
        let xs = [15.0, 20.0, 35.0, 40.0, 50.0];
        // Classic nearest-rank worked example: p30 of this vector is 20.
        assert_eq!(percentile(&xs, 30.0), 20.0);
        assert_eq!(percentile(&xs, 0.0), 15.0);
        assert_eq!(percentile(&xs, 100.0), 50.0);
        // p50 of 1..=100 is 50; p99 is 99 (a value that occurred, not
        // an interpolation).
        let v: Vec<f64> = (1..=100).map(f64::from).collect();
        assert_eq!(percentile(&v, 50.0), 50.0);
        assert_eq!(percentile(&v, 99.0), 99.0);
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }

    #[test]
    fn percentile_guards_empty_and_nan() {
        assert!(percentile(&[], 50.0).is_nan());
        assert!(percentile(&[f64::NAN, f64::INFINITY], 50.0).is_nan());
        // Non-finite entries are ignored, not sorted into the ranks.
        assert_eq!(percentile(&[f64::NAN, 3.0, 1.0, 2.0], 50.0), 2.0);
        // Out-of-range p clamps instead of indexing out of bounds.
        assert_eq!(percentile(&[1.0, 2.0], -5.0), 1.0);
        assert_eq!(percentile(&[1.0, 2.0], 250.0), 2.0);
    }

    #[test]
    fn improvement_pct_is_the_paper_metric() {
        assert_eq!(improvement_pct(200.0, 100.0), 100.0);
        assert_eq!(improvement_pct(100.0, 200.0), -50.0);
        assert_eq!(improvement_pct(150.0, 150.0), 0.0);
    }

    #[test]
    fn improvement_pct_guards_every_degenerate_operand() {
        assert!(improvement_pct(f64::NAN, 100.0).is_nan());
        assert!(improvement_pct(100.0, f64::NAN).is_nan());
        assert!(improvement_pct(f64::INFINITY, 100.0).is_nan());
        assert!(improvement_pct(100.0, 0.0).is_nan(), "instant-profile candidate");
        assert!(improvement_pct(0.0, 0.0).is_nan());
        assert!(improvement_pct(100.0, -1.0).is_nan());
    }
}
