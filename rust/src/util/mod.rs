//! In-crate utilities replacing external dependencies (offline build):
//! a minimal JSON parser ([`json`]), a tiny CLI argument helper
//! ([`cli`]), and a seeded property-testing loop ([`prop`]).

pub mod cli;
pub mod json;
pub mod prop;
