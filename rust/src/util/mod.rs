//! In-crate utilities replacing external dependencies (offline build):
//! a minimal JSON parser ([`json`]), a tiny CLI argument helper
//! ([`cli`]), a seeded property-testing loop ([`prop`]), and shared
//! result arithmetic ([`improvement_pct`]).

pub mod cli;
pub mod json;
pub mod prop;

/// The paper's improvement metric, `(reference / candidate − 1) · 100`,
/// NaN-guarded: a non-finite operand or a zero/negative candidate time
/// (instant profiles, failed rows) yields `NaN` — "unknown", for the
/// caller to render as `-` — never an `inf`/`NaN` walked into a table
/// as if it were a number.  One rule shared by the fig9 driver, the
/// corpus sweep/tuner, and the service demo, so every improvement
/// column in the repo agrees on its edge cases.
pub fn improvement_pct(reference_ms: f64, candidate_ms: f64) -> f64 {
    if reference_ms.is_finite() && candidate_ms.is_finite() && candidate_ms > 0.0 {
        (reference_ms / candidate_ms - 1.0) * 100.0
    } else {
        f64::NAN
    }
}

#[cfg(test)]
mod tests {
    use super::improvement_pct;

    #[test]
    fn improvement_pct_is_the_paper_metric() {
        assert_eq!(improvement_pct(200.0, 100.0), 100.0);
        assert_eq!(improvement_pct(100.0, 200.0), -50.0);
        assert_eq!(improvement_pct(150.0, 150.0), 0.0);
    }

    #[test]
    fn improvement_pct_guards_every_degenerate_operand() {
        assert!(improvement_pct(f64::NAN, 100.0).is_nan());
        assert!(improvement_pct(100.0, f64::NAN).is_nan());
        assert!(improvement_pct(f64::INFINITY, 100.0).is_nan());
        assert!(improvement_pct(100.0, 0.0).is_nan(), "instant-profile candidate");
        assert!(improvement_pct(0.0, 0.0).is_nan());
        assert!(improvement_pct(100.0, -1.0).is_nan());
    }
}
