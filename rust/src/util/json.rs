//! Minimal JSON parser — enough for `artifacts/manifest.json` and run
//! configs.  Supports objects, arrays, strings (with escapes), numbers,
//! booleans and null.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte position.
#[derive(Debug, Clone)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.into() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{s}`")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(key, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = rest.chars().next().unwrap();
                    s.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
        ) {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

/// Escape a string for JSON output.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{"format": "hlo-text/v1", "artifacts": [
            {"name": "nn", "inputs": [{"shape": [16384, 2], "dtype": "f32"}], "flops": 98304}
        ]}"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.get("format").unwrap().as_str(), Some("hlo-text/v1"));
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("name").unwrap().as_str(), Some("nn"));
        let shape = arts[0].get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[0].as_usize(), Some(16384));
        assert_eq!(arts[0].get("flops").unwrap().as_u64(), Some(98304));
    }

    #[test]
    fn parses_escapes_and_numbers() {
        let j = Json::parse(r#"{"s": "a\"b\nc", "n": -1.5e3, "b": true, "z": null}"#).unwrap();
        assert_eq!(j.get("s").unwrap().as_str(), Some("a\"b\nc"));
        assert_eq!(j.get("n").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(j.get("b").unwrap(), &Json::Bool(true));
        assert_eq!(j.get("b").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("z").unwrap().as_bool(), None);
        assert_eq!(j.get("z").unwrap(), &Json::Null);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{} extra").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn roundtrip_escape() {
        let s = "line\n\"quoted\"\tend";
        let doc = format!("{{\"k\": \"{}\"}}", escape(s));
        let j = Json::parse(&doc).unwrap();
        assert_eq!(j.get("k").unwrap().as_str(), Some(s));
    }
}
