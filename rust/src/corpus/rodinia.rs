//! Rodinia suite descriptors (18 applications, 77 configurations).
//!
//! Input labels follow Table 1; byte/FLOP models follow each
//! benchmark's published structure (Che et al., IISWC'09).

use crate::analysis::DependencyFacts;

use super::{mk, Backing, BenchConfig, Suite};

pub fn configs() -> Vec<BenchConfig> {
    let s = Suite::Rodinia;
    let mut v = Vec::new();

    // backprop: feed-forward net; weight matrices are consumed by every
    // task -> SYNC.  Input 10x{2^16..2^20} connections.
    v.extend(mk(s, "backprop", DependencyFacts::sync(), Backing::Burner, &[
        ("10x2^16", 5.0, 0.5, 130.0, 2),
        ("10x2^17", 10.0, 1.0, 260.0, 2),
        ("10x2^18", 20.0, 2.0, 525.0, 2),
        ("10x2^19", 40.0, 4.0, 1050.0, 2),
        ("10x2^20", 80.0, 8.0, 2100.0, 2),
    ]));

    // bfs: frontier expansion loops on the resident graph -> Iterative.
    v.extend(mk(s, "bfs", DependencyFacts::iterative(), Backing::Burner, &[
        ("graph512K", 14.0, 2.0, 3.0, 12),
        ("graph1M", 28.0, 4.0, 6.0, 14),
        ("graph2M", 56.0, 8.0, 12.0, 16),
        ("graph4M", 112.0, 16.0, 24.0, 18),
        ("graph8M", 224.0, 32.0, 48.0, 20),
    ]));

    // b+tree: independent range queries over an uploaded tree.
    v.extend(mk(s, "b+tree", DependencyFacts::independent(), Backing::Burner, &[
        ("Kernel1", 48.0, 6.0, 2900.0, 1),
        ("Kernel2", 48.0, 12.0, 5400.0, 1),
    ]));

    // cfd: Euler solver, time-stepping on resident data -> Iterative.
    v.extend(mk(s, "cfd", DependencyFacts::iterative(), Backing::Burner, &[
        ("0.97K", 0.9, 0.3, 2.5, 200),
        ("193K", 22.0, 7.4, 120.0, 200),
        ("0.2M", 23.0, 7.7, 125.0, 200),
    ]));

    // dwt2d: 2D wavelet; block transforms share boundary pixels (RAR).
    v.extend(mk(s, "dwt2d", DependencyFacts::rar(4, 1024), Backing::Burner, &[
        ("2^10", 4.0, 4.0, 21.0, 1),
        ("2^11", 16.0, 16.0, 84.0, 1),
        ("2^12", 64.0, 64.0, 336.0, 1),
        ("2^13", 256.0, 256.0, 1344.0, 1),
    ]));

    // gaussian: elimination rows depend on the pivot row -> RAW.
    v.extend(mk(s, "gaussian", DependencyFacts::raw(), Backing::Burner, &[
        ("n=1024", 4.0, 4.0, 715.0, 1),
        ("n=2048", 16.0, 16.0, 5726.0, 1),
        ("n=3072", 36.0, 36.0, 19327.0, 1),
        ("n=4096", 64.0, 64.0, 45812.0, 1),
    ]));

    // lud: blocked LU decomposition wavefront -> RAW.
    v.extend(mk(s, "lud", DependencyFacts::raw(), Backing::Burner, &[
        ("256", 0.25, 0.25, 22.0, 1),
        ("512", 1.0, 1.0, 89.0, 1),
        ("1024", 4.0, 4.0, 715.0, 1),
        ("2048", 16.0, 16.0, 5726.0, 1),
        ("4096", 64.0, 64.0, 45812.0, 1),
    ]));

    // heartwall: enormous tracking kernel iterating over frames; KEX
    // dominates end-to-end on any platform (§4.1) -> Iterative.
    v.extend(mk(s, "heartwall", DependencyFacts::iterative(), Backing::Burner, &[
        ("frames=10", 28.0, 0.5, 210.0, 10),
        ("frames=30", 28.0, 1.5, 210.0, 30),
        ("frames=100", 28.0, 5.0, 210.0, 100),
    ]));

    // hotspot: thermal grid, time-stepping on resident data -> Iterative.
    v.extend(mk(s, "hotspot", DependencyFacts::iterative(), Backing::Burner, &[
        ("2^9", 2.0, 1.0, 2.4, 100),
        ("2^10", 8.0, 4.0, 9.4, 100),
        ("2^11", 32.0, 16.0, 38.0, 100),
        ("2^12", 128.0, 64.0, 151.0, 100),
        ("2^13", 256.0, 128.0, 302.0, 100),
    ]));

    // kmeans: membership/centroid loop on resident points -> Iterative.
    v.extend(mk(s, "kmeans", DependencyFacts::iterative(), Backing::Burner, &[
        ("1x10^5", 13.0, 0.4, 82.0, 20),
        ("3x10^5", 40.0, 1.2, 245.0, 20),
        ("10x10^5", 132.0, 4.0, 820.0, 20),
        ("30x10^4x200", 80.0, 2.4, 490.0, 20),
        ("100x10^3x400", 53.0, 1.6, 328.0, 20),
    ]));

    // lavaMD: particle potentials; neighbour-box reads are RAR with a
    // halo comparable to the task size — the paper's negative case (§5).
    v.extend(mk(s, "lavaMD", DependencyFacts::rar(111, 250), Backing::Real("lavamd_box"), &[
        ("boxes=10", 2.4, 2.4, 530.0, 1),
        ("boxes=20", 19.0, 19.0, 4240.0, 1),
        ("boxes=30", 65.0, 65.0, 14310.0, 1),
        ("boxes=40", 154.0, 154.0, 33920.0, 1),
        ("boxes=50", 240.0, 240.0, 66250.0, 1),
    ]));

    // leukocyte: cell tracking across frames -> Iterative.
    v.extend(mk(s, "leukocyte", DependencyFacts::iterative(), Backing::Burner, &[
        ("frames=100", 2.8, 0.1, 470.0, 100),
        ("frames=200", 2.8, 0.2, 470.0, 200),
        ("frames=400", 2.8, 0.4, 470.0, 400),
    ]));

    // myocyte: ODE solver whose kernel runs sequentially — no
    // concurrent tasks exist (§4.1).
    v.extend(mk(
        s,
        "myocyte",
        DependencyFacts { sequential_kernel: true, ..DependencyFacts::independent() },
        Backing::Burner,
        &[
            ("time=100", 0.1, 0.5, 310.0, 100),
            ("time=300", 0.1, 1.5, 310.0, 300),
            ("time=500", 0.1, 2.5, 310.0, 500),
        ],
    ));

    // nn: embarrassingly independent distance computation (Fig. 6).
    // KEX ≈ 33% on MIC (Fig. 4); transfers dominate.
    v.extend(mk(s, "nn", DependencyFacts::independent(), Backing::Real("nn_dist"), &[
        ("100x2^10", 0.8, 0.4, 1.6, 1),
        ("100x2^11", 1.6, 0.8, 3.2, 1),
        ("100x2^12", 3.2, 1.6, 6.4, 1),
        ("100x2^13", 6.4, 3.2, 12.8, 1),
        ("100x2^14", 12.8, 6.4, 25.6, 1),
    ]));

    // nw: Needleman–Wunsch anti-diagonal DP -> RAW (Fig. 8).
    v.extend(mk(s, "nw", DependencyFacts::raw(), Backing::Real("nw_tile"), &[
        ("2^10", 8.0, 4.0, 5.2, 1),
        ("2^11", 32.0, 16.0, 21.0, 1),
        ("2^12", 128.0, 64.0, 84.0, 1),
        ("2^13", 256.0, 128.0, 168.0, 1),
        ("2^14", 256.0, 128.0, 170.0, 1),
    ]));

    // pathfinder: row-by-row DP on a grid -> RAW.
    v.extend(mk(s, "pathfinder", DependencyFacts::raw(), Backing::Burner, &[
        ("10^5x100", 40.0, 0.4, 30.0, 1),
        ("2x10^5x100", 80.0, 0.8, 60.0, 1),
        ("4x10^5x100", 160.0, 1.6, 120.0, 1),
        ("10^5x200", 40.0, 0.4, 60.0, 1),
        ("10^5x400", 40.0, 0.4, 120.0, 1),
    ]));

    // srad: speckle-reducing diffusion, iterative stencil.
    v.extend(mk(s, "srad", DependencyFacts::iterative(), Backing::Burner, &[
        ("100 iter", 16.0, 16.0, 50.0, 100),
        ("200 iter", 16.0, 16.0, 50.0, 200),
        ("300 iter", 16.0, 16.0, 50.0, 300),
        ("400 iter", 16.0, 16.0, 50.0, 400),
        ("500 iter", 16.0, 16.0, 50.0, 500),
    ]));

    // hotspot/srad-like: streamcluster re-clusters resident points each
    // phase; the paper notes it spans multiple categories — dominated by
    // its iterative phase structure.
    v.extend(mk(s, "streamcluster", DependencyFacts::iterative(), Backing::Burner, &[
        ("100x2^10", 0.4, 0.1, 6.0, 50),
        ("100x2^11", 0.8, 0.1, 12.0, 50),
        ("100x2^12", 1.6, 0.2, 24.0, 50),
        ("100x2^13", 3.2, 0.4, 48.0, 50),
        ("100x2^14", 6.4, 0.8, 96.0, 50),
    ]));

    v
}
