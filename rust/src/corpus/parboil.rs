//! Parboil suite descriptors (9 applications, 21 configurations).

use crate::analysis::DependencyFacts;

use super::{mk, Backing, BenchConfig, Suite};

pub fn configs() -> Vec<BenchConfig> {
    let s = Suite::Parboil;
    let mut v = Vec::new();

    // bfs: level-synchronous traversal on the resident graph.
    v.extend(mk(s, "bfs-parboil", DependencyFacts::iterative(), Backing::Burner, &[
        ("1M", 28.0, 4.0, 6.0, 14),
        ("NY", 12.0, 2.0, 3.0, 20),
        ("SF", 18.0, 3.0, 4.5, 22),
        ("UT", 8.0, 1.5, 2.0, 16),
    ]));

    // cutcp: Coulomb potential on a lattice; the *atom list* is read by
    // every lattice task -> SYNC.
    v.extend(mk(s, "cutcp", DependencyFacts::sync(), Backing::Burner, &[
        ("small", 1.2, 16.0, 1900.0, 1),
        ("large", 4.8, 64.0, 7800.0, 1),
    ]));

    // lbm: lattice-Boltzmann, time-stepping -> Iterative.  Fig. 2's
    // dataset study: `short` runs few steps (transfer-heavy), `long`
    // many steps (compute-heavy).
    v.extend(mk(s, "lbm", DependencyFacts::iterative(), Backing::Burner, &[
        ("short", 96.0, 96.0, 270.0, 20),
        ("long", 96.0, 96.0, 270.0, 600),
    ]));

    // mri-gridding: independent sample scatter with host merge.
    v.extend(mk(s, "mri-gridding", DependencyFacts::independent(), Backing::Burner, &[
        ("small", 12.0, 48.0, 2100.0, 1),
    ]));

    // mri-q: pointwise Q-matrix computation, independent.
    v.extend(mk(s, "mri-q", DependencyFacts::independent(), Backing::Burner, &[
        ("small", 1.5, 1.0, 800.0, 1),
        ("large", 6.0, 4.0, 3300.0, 1),
    ]));

    // sgemm: row-band matmul; bands independent (B broadcast).
    v.extend(mk(s, "sgemm", DependencyFacts::independent(), Backing::Real("matmul"), &[
        ("small", 1.5, 0.5, 330.0, 1),
        ("medium", 6.0, 2.0, 2650.0, 1),
    ]));

    // spmv: rows independent given the vector.
    v.extend(mk(s, "spmv", DependencyFacts::independent(), Backing::Burner, &[
        ("small", 3.0, 0.3, 5.8, 1),
        ("medium", 12.0, 1.2, 23.0, 1),
        ("large", 48.0, 4.8, 92.0, 1),
    ]));

    // stencil: 7-point Jacobi over a 3D grid; halo RAR between bands.
    v.extend(mk(s, "stencil", DependencyFacts::rar(1, 128), Backing::Real("stencil2d"), &[
        ("small", 16.0, 16.0, 25.0, 1),
        ("default", 64.0, 64.0, 100.0, 1),
    ]));

    // tpacf: angular correlation histograms over *all pairs* — every
    // task reads the whole point set -> SYNC.
    v.extend(mk(s, "tpacf", DependencyFacts::sync(), Backing::Burner, &[
        ("small", 1.0, 0.01, 2600.0, 1),
        ("medium", 2.0, 0.01, 10400.0, 1),
        ("large", 4.0, 0.01, 41600.0, 1),
    ]));

    v
}
