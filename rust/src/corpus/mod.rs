//! The Table-1 benchmark corpus: 56 applications × 223 input
//! configurations from Rodinia, Parboil, the NVIDIA SDK and the AMD APP
//! SDK, encoded as workload descriptors.
//!
//! Each descriptor records the byte/FLOP profile of one (app, input)
//! pair plus the dependency facts the Table-2 categorizer consumes.
//! Sixteen benchmarks are **Real**-backed (their chunk kernels are AOT
//! Pallas artifacts, exercised by [`crate::workloads`]); the rest are
//! **Burner**-backed: their stage profile drives the same engines with
//! the calibrated synthetic kernel (DESIGN.md §2 substitution table).
//!
//! Byte/FLOP models are reconstructed from each benchmark's published
//! structure (input layouts, per-element op counts, iteration counts) —
//! the paper does not publish per-config numbers, so the *distribution*
//! (which codes are transfer-bound vs compute-bound vs iterative) is the
//! reproduction target, per DESIGN.md §5/E1.

mod amd;
mod nvidia;
mod parboil;
mod rodinia;

use crate::analysis::{categorize, Category, DependencyFacts};

/// Benchmark suite of origin (Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Suite {
    Rodinia,
    Parboil,
    NvidiaSdk,
    AmdSdk,
}

impl Suite {
    pub fn label(self) -> &'static str {
        match self {
            Suite::Rodinia => "Rodinia",
            Suite::Parboil => "Parboil",
            Suite::NvidiaSdk => "NVIDIA SDK",
            Suite::AmdSdk => "AMD SDK",
        }
    }
}

/// How KEX is realized on the simulator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backing {
    /// A real AOT Pallas artifact (name).
    Real(&'static str),
    /// The calibrated synthetic burner under a FLOP override.
    Burner,
}

/// One (application, input configuration) descriptor.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub suite: Suite,
    pub app: &'static str,
    /// Human-readable input label from Table 1.
    pub config: String,
    /// Host→device payload (all input buffers).
    pub h2d_bytes: u64,
    /// Device→host payload (all output buffers).
    pub d2h_bytes: u64,
    /// Total kernel FLOPs across all iterations.
    pub flops: u64,
    /// KEX invocations on resident data (1 = single-shot).
    pub kex_iterations: u32,
    /// Dependency facts for the Table-2 categorizer.
    pub facts: DependencyFacts,
    pub backing: Backing,
}

impl BenchConfig {
    /// Table-2 category of this benchmark.
    pub fn category(&self) -> Category {
        categorize(&self.facts)
    }

    /// FLOPs per kernel invocation.
    pub fn flops_per_iteration(&self) -> u64 {
        self.flops / self.kex_iterations.max(1) as u64
    }
}

/// Internal row format used by the suite tables:
/// (label, h2d_mb, d2h_mb, mflop_per_iter, iterations).
pub(crate) type Row = (&'static str, f64, f64, f64, u32);

pub(crate) fn mk(
    suite: Suite,
    app: &'static str,
    facts: DependencyFacts,
    backing: Backing,
    rows: &[Row],
) -> Vec<BenchConfig> {
    rows.iter()
        .map(|(label, h2d_mb, d2h_mb, mflop, iters)| BenchConfig {
            suite,
            app,
            config: label.to_string(),
            h2d_bytes: (h2d_mb * 1024.0 * 1024.0) as u64,
            d2h_bytes: (d2h_mb * 1024.0 * 1024.0) as u64,
            flops: (mflop * 1e6) as u64 * *iters as u64,
            kex_iterations: *iters,
            facts,
            backing,
        })
        .collect()
}

/// Every (app, config) descriptor in the corpus — the Fig. 1 population.
pub fn all_configs() -> Vec<BenchConfig> {
    let mut v = Vec::with_capacity(223);
    v.extend(rodinia::configs());
    v.extend(parboil::configs());
    v.extend(nvidia::configs());
    v.extend(amd::configs());
    v
}

/// Unique application names (one Table-2 row each).
pub fn apps() -> Vec<(&'static str, Suite, Category)> {
    let mut seen = std::collections::HashSet::new();
    let mut out = Vec::new();
    for c in all_configs() {
        if seen.insert((c.app, c.suite)) {
            out.push((c.app, c.suite, c.category()));
        }
    }
    out
}

/// Descriptors for one app (its input sweep).
pub fn configs_for(app: &str) -> Vec<BenchConfig> {
    all_configs().into_iter().filter(|c| c.app == app).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_size_matches_paper() {
        // Table 1: 56 benchmarks, 223 configurations.
        assert_eq!(apps().len(), 56, "benchmark count");
        assert_eq!(all_configs().len(), 223, "configuration count");
    }

    #[test]
    fn suites_match_table1_counts() {
        let apps = apps();
        let count = |s: Suite| apps.iter().filter(|(_, suite, _)| *suite == s).count();
        assert_eq!(count(Suite::Rodinia), 18);
        assert_eq!(count(Suite::Parboil), 9);
        assert_eq!(count(Suite::NvidiaSdk), 17);
        assert_eq!(count(Suite::AmdSdk), 12);
    }

    #[test]
    fn every_config_is_physical() {
        for c in all_configs() {
            assert!(c.h2d_bytes > 0, "{}: zero h2d", c.app);
            assert!(c.flops > 0, "{}: zero flops", c.app);
            assert!(c.kex_iterations >= 1);
            // Keep the survey runnable: payloads bounded.
            assert!(c.h2d_bytes <= 256 << 20, "{}: h2d too large", c.app);
        }
    }

    #[test]
    fn paper_exemplars_categorized() {
        let find = |app: &str| {
            apps().into_iter().find(|(a, _, _)| *a == app).map(|(_, _, c)| c).unwrap()
        };
        assert_eq!(find("nn"), Category::Independent);
        assert_eq!(find("FastWalshTransform"), Category::FalseDependent);
        assert_eq!(find("nw"), Category::TrueDependent);
        assert_eq!(find("lavaMD"), Category::FalseDependent);
        assert_eq!(find("myocyte"), Category::Iterative);
        assert_eq!(find("backprop"), Category::Sync);
    }

    #[test]
    fn streamed_benchmarks_are_real_backed() {
        // The 13 Fig. 9 benchmarks must run real kernels.
        for app in [
            "nn",
            "FastWalshTransform",
            "ConvolutionFFT2D",
            "nw",
            "lavaMD",
            "ConvolutionSeparable",
            "Transpose",
            "PrefixSum",
            "Histogram",
            "MatrixMul",
            "VectorAdd",
            "BlackScholes",
            "stencil",
        ] {
            let cs = configs_for(app);
            assert!(!cs.is_empty(), "missing {app}");
            assert!(
                matches!(cs[0].backing, Backing::Real(_)),
                "{app} should be Real-backed"
            );
        }
    }
}
