//! AMD APP SDK suite descriptors (12 applications, 48 configurations).

use crate::analysis::DependencyFacts;

use super::{mk, Backing, BenchConfig, Suite};

pub fn configs() -> Vec<BenchConfig> {
    let s = Suite::AmdSdk;
    let mut v = Vec::new();

    // BinomialOption: one independent lattice walk per option,
    // compute-bound.
    v.extend(mk(s, "BinomialOption", DependencyFacts::independent(), Backing::Burner, &[
        ("2^10x1", 0.02, 0.004, 530.0, 1),
        ("2^10x2", 0.03, 0.008, 1060.0, 1),
        ("2^10x4", 0.07, 0.016, 2120.0, 1),
        ("2^10x8", 0.13, 0.03, 4240.0, 1),
        ("2^10x16", 0.26, 0.07, 8480.0, 1),
    ]));

    // BitonicSort: log^2(n) passes over the resident array -> Iterative.
    v.extend(mk(s, "BitonicSort", DependencyFacts::iterative(), Backing::Burner, &[
        ("2^20x1", 4.0, 4.0, 2.1, 210),
        ("2^20x2", 8.0, 8.0, 4.2, 231),
        ("2^20x4", 16.0, 16.0, 8.4, 253),
        ("2^20x8", 32.0, 32.0, 16.8, 276),
        ("2^20x16", 64.0, 64.0, 33.6, 300),
    ]));

    // BoxFilter: sliding-window blur; window overlap is RAR halo.
    v.extend(mk(s, "BoxFilter", DependencyFacts::rar(10, 1024), Backing::Burner, &[
        ("BoxFilter_Input", 4.0, 4.0, 260.0, 1),
    ]));

    // DwtHaar1D: block Haar transform with boundary coefficients (RAR).
    v.extend(mk(s, "DwtHaar1D", DependencyFacts::rar(1, 512), Backing::Burner, &[
        ("2^10x10^3x1", 4.0, 4.0, 8.4, 1),
        ("2^10x10^3x2", 8.0, 8.0, 16.8, 1),
        ("2^10x10^3x3", 12.0, 12.0, 25.2, 1),
        ("2^10x10^3x4", 16.0, 16.0, 33.6, 1),
        ("2^10x10^3x8", 32.0, 32.0, 67.2, 1),
    ]));

    // FloydWarshall: k-loop over the resident distance matrix ->
    // Iterative.
    v.extend(mk(s, "FloydWarshall", DependencyFacts::iterative(), Backing::Burner, &[
        ("2^10x1", 4.0, 4.0, 2.1, 1024),
        ("2^10x2", 16.0, 16.0, 8.4, 2048),
        ("2^10x3", 36.0, 36.0, 18.9, 3072),
        ("2^10x4", 64.0, 64.0, 33.6, 4096),
        ("2^10x5", 100.0, 100.0, 52.5, 5120),
    ]));

    // MonteCarloAsian: independent paths, compute-bound.
    v.extend(mk(s, "MonteCarloAsian", DependencyFacts::independent(), Backing::Burner, &[
        ("2^10x1", 0.02, 0.01, 1800.0, 1),
        ("2^10x2", 0.03, 0.02, 3600.0, 1),
        ("2^10x3", 0.05, 0.02, 5400.0, 1),
        ("2^10x4", 0.07, 0.03, 7200.0, 1),
        ("2^10x5", 0.08, 0.04, 9000.0, 1),
    ]));

    // PrefixSum: per-chunk scans + tiny host carry pass (paper's ps).
    v.extend(mk(s, "PrefixSum", DependencyFacts::independent(), Backing::Real("prefix_sum"), &[
        ("1024k", 4.0, 4.0, 1.05, 1),
    ]));

    // RadixSort: digit passes over resident keys -> Iterative.
    v.extend(mk(s, "RadixSort", DependencyFacts::iterative(), Backing::Burner, &[
        ("2^12x12", 0.19, 0.19, 0.4, 32),
        ("2^12x13", 0.2, 0.2, 0.44, 32),
        ("2^12x14", 0.22, 0.22, 0.44, 32),
        ("2^12x15", 0.23, 0.23, 0.48, 32),
        ("2^12x16", 0.25, 0.25, 0.52, 32),
    ]));

    // RecursiveGaussian: independent row/column IIR passes.
    v.extend(mk(s, "RecursiveGaussian", DependencyFacts::independent(), Backing::Burner, &[
        ("default", 4.0, 4.0, 210.0, 1),
    ]));

    // ScanLargeArrays: same scan-and-carry structure as PrefixSum.
    #[rustfmt::skip]
    v.extend(mk(s, "ScanLargeArrays", DependencyFacts::independent(), Backing::Real("prefix_sum"), &[
        ("2^10x1", 4.0, 4.0, 1.05, 1),
        ("2^10x2", 8.0, 8.0, 2.1, 1),
        ("2^10x4", 16.0, 16.0, 4.2, 1),
        ("2^10x8", 32.0, 32.0, 8.4, 1),
        ("2^10x16", 64.0, 64.0, 16.8, 1),
    ]));

    // StringSearch: text chunks overlap by pattern length (RAR).
    v.extend(mk(s, "StringSearch", DependencyFacts::rar(32, 65536), Backing::Burner, &[
        ("1", 8.0, 0.1, 400.0, 1),
        ("2", 16.0, 0.2, 800.0, 1),
        ("3", 24.0, 0.3, 1200.0, 1),
        ("4", 32.0, 0.4, 1600.0, 1),
        ("5", 40.0, 0.5, 2000.0, 1),
    ]));

    // URNG: pointwise noise generation.
    v.extend(mk(s, "URNG", DependencyFacts::independent(), Backing::Burner, &[
        ("1", 4.0, 4.0, 4.2, 1),
        ("2", 8.0, 8.0, 8.4, 1),
        ("3", 12.0, 12.0, 12.6, 1),
        ("4", 16.0, 16.0, 16.8, 1),
        ("5", 20.0, 20.0, 21.0, 1),
    ]));

    v
}
