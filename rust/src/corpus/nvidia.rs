//! NVIDIA SDK suite descriptors (17 applications, 77 configurations).

use crate::analysis::DependencyFacts;

use super::{mk, Backing, BenchConfig, Suite};

pub fn configs() -> Vec<BenchConfig> {
    let s = Suite::NvidiaSdk;
    let mut v = Vec::new();

    // BlackScholes: pointwise option pricing — three arrays in, two out.
    #[rustfmt::skip]
    v.extend(mk(s, "BlackScholes", DependencyFacts::independent(), Backing::Real("black_scholes"), &[
        ("10^6x4", 48.0, 32.0, 240.0, 1),
        ("10^6x8", 96.0, 64.0, 480.0, 1),
        ("10^6x12", 144.0, 96.0, 720.0, 1),
        ("10^6x16", 192.0, 128.0, 960.0, 1),
        ("10^6x20", 240.0, 160.0, 1200.0, 1),
    ]));

    // ConvolutionFFT2D: tiled spectral convolution, apron overlap (RAR).
    v.extend(mk(s, "ConvolutionFFT2D", DependencyFacts::rar(16, 16384), Backing::Real("cfft2d"), &[
        ("6", 16.0, 16.0, 110.0, 1),
        ("7", 32.0, 32.0, 235.0, 1),
        ("8", 64.0, 64.0, 500.0, 1),
        ("9", 128.0, 128.0, 1060.0, 1),
    ]));

    // ConvolutionSeparable: row/col passes share halo rows (RAR).
    // Paper §5: R ≈ 19%, streamed gain ≈ 45%.
    #[rustfmt::skip]
    v.extend(mk(s, "ConvolutionSeparable", DependencyFacts::rar(8, 128), Backing::Real("conv_sep"), &[
        ("2^10x1", 4.0, 4.0, 140.0, 1),
        ("2^10x2", 8.0, 8.0, 285.0, 1),
        ("2^10x3", 12.0, 12.0, 430.0, 1),
        ("2^10x4", 16.0, 16.0, 570.0, 1),
        ("2^10x8", 32.0, 32.0, 1140.0, 1),
    ]));

    // DCT8x8: independent 8x8 blocks.
    v.extend(mk(s, "DCT8x8", DependencyFacts::independent(), Backing::Burner, &[
        ("2^10x1", 4.0, 4.0, 270.0, 1),
        ("2^10x2", 8.0, 8.0, 540.0, 1),
        ("2^10x3", 12.0, 12.0, 810.0, 1),
        ("2^10x4", 16.0, 16.0, 1080.0, 1),
        ("2^10x8", 32.0, 32.0, 2160.0, 1),
    ]));

    // DotProduct: independent partial products + tiny reduce.
    v.extend(mk(s, "DotProduct", DependencyFacts::independent(), Backing::Burner, &[
        ("2^10x10^3x1", 8.0, 0.01, 2.1, 1),
        ("2^10x10^3x2", 16.0, 0.01, 4.2, 1),
        ("2^10x10^3x3", 24.0, 0.01, 6.3, 1),
        ("2^10x10^3x4", 32.0, 0.01, 8.4, 1),
        ("2^10x10^3x8", 64.0, 0.01, 16.8, 1),
    ]));

    // DXTCompression: independent 4x4 texel blocks (lena input).
    v.extend(mk(s, "DXTCompression", DependencyFacts::independent(), Backing::Burner, &[
        ("lena", 1.0, 0.13, 210.0, 1),
    ]));

    // FDTD3d: time-stepped 3D stencil -> Iterative.  Fig. 2: R falls as
    // the user raises the timestep count.
    v.extend(mk(s, "FDTD3d", DependencyFacts::iterative(), Backing::Burner, &[
        ("steps=10", 55.0, 55.0, 190.0, 10),
        ("steps=20", 55.0, 55.0, 190.0, 20),
        ("steps=30", 55.0, 55.0, 190.0, 30),
        ("steps=40", 55.0, 55.0, 190.0, 40),
        ("steps=50", 55.0, 55.0, 190.0, 50),
    ]));

    // FastWalshTransform: block butterflies share boundary reads (RAR);
    // boundary (254) << task (1M) so streaming pays (§5).
    #[rustfmt::skip]
    v.extend(mk(s, "FastWalshTransform", DependencyFacts::rar(127, 1 << 20), Backing::Real("fwt"), &[
        ("2^20x1", 4.0, 4.0, 44.0, 1),
        ("2^20x2", 8.0, 8.0, 92.0, 1),
        ("2^20x4", 16.0, 16.0, 192.0, 1),
        ("2^20x8", 32.0, 32.0, 400.0, 1),
        ("2^20x16", 64.0, 64.0, 832.0, 1),
    ]));

    // Histogram: independent per-chunk counts, 1KB D2H (paper's hg).
    v.extend(mk(s, "Histogram", DependencyFacts::independent(), Backing::Real("histogram"), &[
        ("2^10x10^3x1", 4.0, 0.001, 2.1, 1),
        ("2^10x10^3x2", 8.0, 0.001, 4.2, 1),
        ("2^10x10^3x3", 12.0, 0.001, 6.3, 1),
        ("2^10x10^3x4", 16.0, 0.001, 8.4, 1),
        ("2^10x10^3x8", 32.0, 0.001, 16.8, 1),
    ]));

    // MatVecMul: matrix rows independent (small broadcast vector).
    v.extend(mk(s, "MatVecMul", DependencyFacts::independent(), Backing::Burner, &[
        ("n=1", 4.0, 0.01, 2.1, 1),
        ("n=2", 8.0, 0.01, 4.2, 1),
        ("n=3", 16.0, 0.02, 8.4, 1),
        ("n=4", 32.0, 0.03, 16.8, 1),
        ("n=5", 64.0, 0.06, 33.6, 1),
    ]));

    // MatrixMul: row bands of A independent; compute-bound.
    v.extend(mk(s, "MatrixMul", DependencyFacts::independent(), Backing::Real("matmul"), &[
        ("512", 2.0, 1.0, 268.0, 1),
        ("1024", 8.0, 4.0, 2150.0, 1),
        ("1536", 18.0, 9.0, 7250.0, 1),
        ("2048", 32.0, 16.0, 17180.0, 1),
    ]));

    // QuasirandomGenerator: output-only generation (tiny H2D).
    v.extend(mk(s, "QuasirandomGenerator", DependencyFacts::independent(), Backing::Burner, &[
        ("2^20", 0.01, 12.0, 63.0, 1),
        ("2^21", 0.01, 24.0, 126.0, 1),
        ("2^22", 0.01, 48.0, 252.0, 1),
        ("2^23", 0.01, 96.0, 504.0, 1),
    ]));

    // Reduction (v1): full device-side sum, scalar D2H (Fig. 3).
    v.extend(mk(s, "Reduction", DependencyFacts::independent(), Backing::Real("reduction_v1"), &[
        ("2^20", 4.0, 0.000004, 1.05, 1),
        ("2^21", 8.0, 0.000004, 2.1, 1),
        ("2^22", 16.0, 0.000004, 4.2, 1),
        ("2^23", 32.0, 0.000004, 8.4, 1),
        ("2^24", 64.0, 0.000004, 16.8, 1),
    ]));

    // Reduction-2 (v2): partial sums return to the host (Fig. 3's
    // transfer-heavier variant).
    v.extend(mk(s, "Reduction-2", DependencyFacts::independent(), Backing::Real("reduction_v2"), &[
        ("2^20", 4.0, 0.25, 1.05, 1),
        ("2^21", 8.0, 0.5, 2.1, 1),
        ("2^22", 16.0, 1.0, 4.2, 1),
        ("2^23", 32.0, 2.0, 8.4, 1),
        ("2^24", 64.0, 4.0, 16.8, 1),
    ]));

    // Transpose: independent row bands.  Paper §5: R ≈ 14%, gain ≈ 11%;
    // 400M vs 64M datasets give R 20% vs 10%.
    v.extend(mk(s, "Transpose", DependencyFacts::independent(), Backing::Real("transpose"), &[
        ("64M", 64.0, 64.0, 1100.0, 1),
        ("128M", 128.0, 128.0, 2200.0, 1),
        ("256M", 256.0, 256.0, 4400.0, 1),
        ("400M", 200.0, 200.0, 2750.0, 1),
        ("2^10x8", 32.0, 32.0, 550.0, 1),
    ]));

    // Tridiagonal: cyclic-reduction recurrence -> RAW.
    v.extend(mk(s, "Tridiagonal", DependencyFacts::raw(), Backing::Burner, &[
        ("10", 8.0, 2.7, 22.0, 1),
        ("20", 16.0, 5.4, 44.0, 1),
        ("30", 24.0, 8.1, 66.0, 1),
        ("40", 32.0, 10.8, 88.0, 1),
    ]));

    // VectorAdd: the minimal streamable pointwise code.
    v.extend(mk(s, "VectorAdd", DependencyFacts::independent(), Backing::Real("vector_add"), &[
        ("2^10x1", 8.0, 4.0, 1.05, 1),
        ("2^10x2", 16.0, 8.0, 2.1, 1),
        ("2^10x3", 24.0, 12.0, 3.1, 1),
        ("2^10x4", 32.0, 16.0, 4.2, 1),
        ("2^10x8", 64.0, 32.0, 8.4, 1),
    ]));

    v
}
