//! Completion events — the cross-stream synchronization primitive.

use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::device::SimTime;

/// Timing sample recorded when an op retires: a span on the context's
/// simulation timeline.  Under `TimeMode::Virtual` these are
/// discrete-event timestamps (deterministic, bit-identical across
/// runs); under `TimeMode::Wallclock` they are wall-clock offsets from
/// the context epoch.  Either way they are totally ordered and
/// directly comparable across streams and engines.
#[derive(Debug, Clone, Copy)]
pub struct Sample {
    /// When the op started occupying its engine (after dep waits).
    pub start: SimTime,
    /// When the op retired (modeled duration included).
    pub end: SimTime,
}

impl Sample {
    pub fn duration(&self) -> Duration {
        self.end - self.start
    }
}

#[derive(Default)]
struct Inner {
    state: Mutex<Option<Sample>>,
    cv: Condvar,
}

/// A one-shot completion event, cloneable across threads.  Engines
/// complete it with a timing [`Sample`]; streams and host code wait on
/// it (parking, not spinning).
#[derive(Clone, Default)]
pub struct Event(Arc<Inner>);

impl Event {
    pub fn new() -> Self {
        Self::default()
    }

    /// Mark complete with its timing sample.  Completing twice panics —
    /// that would mean two engines retired the same op.
    pub fn complete(&self, sample: Sample) {
        let mut st = self.0.state.lock().unwrap();
        assert!(st.is_none(), "event completed twice");
        *st = Some(sample);
        self.0.cv.notify_all();
    }

    /// Block until complete; returns the op's timing sample.
    pub fn wait(&self) -> Sample {
        let mut st = self.0.state.lock().unwrap();
        while st.is_none() {
            st = self.0.cv.wait(st).unwrap();
        }
        st.unwrap()
    }

    /// Non-blocking completion check.
    pub fn is_done(&self) -> bool {
        self.0.state.lock().unwrap().is_some()
    }

    /// Timing sample if already complete.
    pub fn sample(&self) -> Option<Sample> {
        *self.0.state.lock().unwrap()
    }
}

/// Timeline span covered by a set of completed events:
/// `max(end) - min(start)`.  Events that have not completed are
/// skipped; an empty or all-pending set yields zero.  This is the
/// mode-agnostic "wall" of a run — in virtual mode it is the modeled
/// makespan, in wall-clock mode the measured one.
pub fn makespan<'a, I>(events: I) -> Duration
where
    I: IntoIterator<Item = &'a Event>,
{
    let mut lo: Option<SimTime> = None;
    let mut hi: Option<SimTime> = None;
    for e in events {
        if let Some(s) = e.sample() {
            lo = Some(lo.map_or(s.start, |v| v.min(s.start)));
            hi = Some(hi.map_or(s.end, |v| v.max(s.end)));
        }
    }
    match (lo, hi) {
        (Some(a), Some(b)) => b - a,
        _ => Duration::ZERO,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn at(start: u64, end: u64) -> Sample {
        Sample { start: SimTime::from_nanos(start), end: SimTime::from_nanos(end) }
    }

    #[test]
    fn wait_blocks_until_complete() {
        let e = Event::new();
        let e2 = e.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            e2.complete(at(0, 5));
        });
        assert!(!e.is_done());
        let s = e.wait();
        assert!(e.is_done());
        assert_eq!(s.duration(), Duration::from_nanos(5));
        h.join().unwrap();
    }

    #[test]
    #[should_panic(expected = "completed twice")]
    fn double_complete_panics() {
        let e = Event::new();
        e.complete(at(0, 0));
        e.complete(at(0, 0));
    }

    #[test]
    fn makespan_spans_completed_events() {
        let a = Event::new();
        let b = Event::new();
        a.complete(at(100, 250));
        b.complete(at(200, 900));
        assert_eq!(makespan([&a, &b]), Duration::from_nanos(800));
        // Pending events are skipped; empty sets are zero.
        let pending = Event::new();
        assert_eq!(makespan([&pending]), Duration::ZERO);
        assert_eq!(makespan([&a, &pending]), Duration::from_nanos(150));
    }
}
