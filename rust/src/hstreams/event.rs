//! Completion events — the cross-stream synchronization primitive.

use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// Timing sample recorded when an op retires.
#[derive(Debug, Clone, Copy)]
pub struct Sample {
    /// When the engine started executing the op (after dep waits).
    pub start: Instant,
    /// When the op retired (pacing included).
    pub end: Instant,
}

impl Sample {
    pub fn duration(&self) -> std::time::Duration {
        self.end - self.start
    }
}

#[derive(Default)]
struct Inner {
    state: Mutex<Option<Sample>>,
    cv: Condvar,
}

/// A one-shot completion event, cloneable across threads.  Engines
/// complete it with a timing [`Sample`]; streams and host code wait on
/// it (parking, not spinning).
#[derive(Clone, Default)]
pub struct Event(Arc<Inner>);

impl Event {
    pub fn new() -> Self {
        Self::default()
    }

    /// Mark complete with its timing sample.  Completing twice panics —
    /// that would mean two engines retired the same op.
    pub fn complete(&self, sample: Sample) {
        let mut st = self.0.state.lock().unwrap();
        assert!(st.is_none(), "event completed twice");
        *st = Some(sample);
        self.0.cv.notify_all();
    }

    /// Block until complete; returns the op's timing sample.
    pub fn wait(&self) -> Sample {
        let mut st = self.0.state.lock().unwrap();
        while st.is_none() {
            st = self.0.cv.wait(st).unwrap();
        }
        st.unwrap()
    }

    /// Non-blocking completion check.
    pub fn is_done(&self) -> bool {
        self.0.state.lock().unwrap().is_some()
    }

    /// Timing sample if already complete.
    pub fn sample(&self) -> Option<Sample> {
        *self.0.state.lock().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn wait_blocks_until_complete() {
        let e = Event::new();
        let e2 = e.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            let now = Instant::now();
            e2.complete(Sample { start: now, end: now });
        });
        assert!(!e.is_done());
        e.wait();
        assert!(e.is_done());
        h.join().unwrap();
    }

    #[test]
    #[should_panic(expected = "completed twice")]
    fn double_complete_panics() {
        let e = Event::new();
        let now = Instant::now();
        e.complete(Sample { start: now, end: now });
        e.complete(Sample { start: now, end: now });
    }
}
