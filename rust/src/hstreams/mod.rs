//! The multi-stream programming model (hStreams / CUDA-streams analog).
//!
//! A [`Context`] owns the simulated device (arena + DMA + compute
//! engines).  A [`Stream`] is a logical in-order pipeline: ops enqueued
//! on it execute in enqueue order; ops on *different* streams may
//! overlap whenever they occupy different engines — which is exactly the
//! paper's mechanism: "the data movement stage of one pipeline overlaps
//! the kernel execution stage of another".
//!
//! Engine queues are FIFO and the queue head blocks on its dependency
//! events (the CUDA-stream hardware model).  Programs must therefore
//! enqueue in a topological order of their task DAG — all partitioners
//! in [`crate::partition`] emit tasks that way.

mod context;
mod event;
mod stream;

pub use context::{Context, ContextBuilder};
pub use event::{makespan, Event, Sample};
pub use stream::{host_dst, host_src_f32, host_src_i32, Stream};
