//! In-order logical streams.

use std::sync::Arc;

use std::sync::Mutex;

use crate::device::{DevRegion, Direction, HostDst, HostSrc, KernelJob, TransferJob};

use super::context::Context;
use super::event::Event;

/// A logical in-order pipeline of H2D / KEX / D2H ops.
///
/// Every op implicitly depends on the stream's previous op (in-order
/// semantics); [`Stream::wait_event`] adds a cross-stream dependency to
/// the *next* enqueued op, mirroring `cudaStreamWaitEvent` /
/// hStreams event waits.
pub struct Stream<'c> {
    ctx: &'c Context,
    id: u64,
    last: Option<Event>,
    pending_waits: Vec<Event>,
    issued: Vec<Event>,
}

impl<'c> Stream<'c> {
    pub(crate) fn new(ctx: &'c Context, id: u64) -> Self {
        Self { ctx, id, last: None, pending_waits: Vec::new(), issued: Vec::new() }
    }

    /// Stream id (diagnostics).
    pub fn id(&self) -> u64 {
        self.id
    }

    fn take_deps(&mut self) -> Vec<Event> {
        let mut deps = Vec::with_capacity(1 + self.pending_waits.len());
        if let Some(last) = &self.last {
            deps.push(last.clone());
        }
        deps.append(&mut self.pending_waits);
        deps
    }

    fn record(&mut self, e: &Event) {
        self.last = Some(e.clone());
        self.issued.push(e.clone());
    }

    /// Enqueue a host→device copy.  Returns the op's completion event.
    pub fn h2d(&mut self, src: HostSrc, dev: DevRegion) -> Event {
        let done = Event::new();
        let deps = self.take_deps();
        self.ctx.dma.submit(TransferJob {
            dir: Direction::H2D,
            src: Some(src),
            dst: None,
            dev,
            deps,
            done: done.clone(),
            seq: self.ctx.next_seq(),
            stream: self.id,
        });
        self.record(&done);
        done
    }

    /// Enqueue a kernel launch.
    pub fn kex(
        &mut self,
        artifact: impl Into<String>,
        inputs: Vec<DevRegion>,
        outputs: Vec<DevRegion>,
    ) -> Event {
        self.kex_with(artifact, inputs, outputs, None, 1)
    }

    /// Kernel launch with a FLOP override and/or repeat count (iterative
    /// kernels, descriptor-backed corpus entries).
    pub fn kex_with(
        &mut self,
        artifact: impl Into<String>,
        inputs: Vec<DevRegion>,
        outputs: Vec<DevRegion>,
        flops: Option<u64>,
        repeats: u32,
    ) -> Event {
        let done = Event::new();
        let deps = self.take_deps();
        self.ctx.kex.submit(KernelJob {
            artifact: artifact.into(),
            inputs,
            outputs,
            flops,
            repeats,
            deps,
            done: done.clone(),
            seq: self.ctx.next_seq(),
            stream: self.id,
        });
        self.record(&done);
        done
    }

    /// Enqueue a device→host copy into `dst.data[dst.off..]`.
    pub fn d2h(&mut self, dev: DevRegion, dst: HostDst) -> Event {
        let done = Event::new();
        let deps = self.take_deps();
        self.ctx.dma.submit(TransferJob {
            dir: Direction::D2H,
            src: None,
            dst: Some(dst),
            dev,
            deps,
            done: done.clone(),
            seq: self.ctx.next_seq(),
            stream: self.id,
        });
        self.record(&done);
        done
    }

    /// Make the next enqueued op also wait for `e` (cross-stream dep).
    pub fn wait_event(&mut self, e: Event) {
        self.pending_waits.push(e);
    }

    /// Block until every op enqueued on this stream has retired.
    pub fn sync(&self) {
        if let Some(last) = &self.last {
            last.wait();
        }
    }

    /// All completion events issued by this stream, in enqueue order.
    pub fn events(&self) -> &[Event] {
        &self.issued
    }
}

/// Convenience: wrap a `Vec<f32>` as an H2D source.
pub fn host_src_f32(v: &[f32]) -> HostSrc {
    HostSrc::whole(Arc::new(crate::runtime::bytes::from_f32(v)))
}

/// Convenience: wrap a `Vec<i32>` as an H2D source.
pub fn host_src_i32(v: &[i32]) -> HostSrc {
    HostSrc::whole(Arc::new(crate::runtime::bytes::from_i32(v)))
}

/// Convenience: a zeroed, shared host destination of `len` bytes.
pub fn host_dst(len: usize) -> HostDst {
    HostDst { data: Arc::new(Mutex::new(vec![0u8; len])), off: 0 }
}
