//! Context: owns the simulated device and hands out streams.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use std::sync::Mutex;

use crate::device::{
    BufId, ComputeEngine, DevRegion, DeviceArena, DeviceProfile, SimClock, SimTime, TimeMode,
    TransferEngine,
};
use crate::Result;

use super::stream::Stream;

/// Builder for [`Context`].
pub struct ContextBuilder {
    profile: DeviceProfile,
    artifacts_dir: PathBuf,
    device_mem: usize,
    compute_workers: usize,
    artifact_subset: Option<Vec<String>>,
    time_mode: TimeMode,
    record_trace: bool,
}

impl ContextBuilder {
    pub fn new() -> Self {
        Self {
            profile: DeviceProfile::mic31sp().simulation(),
            artifacts_dir: crate::artifacts_dir(),
            device_mem: 2 << 30, // 2 GiB of simulated device memory
            compute_workers: 1,
            artifact_subset: None,
            time_mode: TimeMode::from_env_default(),
            record_trace: false,
        }
    }

    /// Device profile (default: the paper's MIC 31SP, time-dilated for
    /// the engines — see [`crate::device::profile`]).  Paper-scale
    /// profiles are dilated automatically; pass a profile whose name
    /// ends in `-sim` (or `instant`) to use it as-is.
    pub fn profile(mut self, p: DeviceProfile) -> Self {
        self.profile = p.simulation();
        self
    }

    /// Where `manifest.json` and the HLO artifacts live.
    pub fn artifacts_dir(mut self, d: impl Into<PathBuf>) -> Self {
        self.artifacts_dir = d.into();
        self
    }

    /// Simulated device memory capacity.
    pub fn device_mem(mut self, bytes: usize) -> Self {
        self.device_mem = bytes;
        self
    }

    /// Number of concurrent kernel queues (1 = one coprocessor queue;
    /// >1 models hStreams core partitioning).
    pub fn compute_workers(mut self, n: usize) -> Self {
        self.compute_workers = n;
        self
    }

    /// Compile only these artifacts (fast startup for focused runs).
    pub fn only_artifacts<S: Into<String>>(mut self, names: impl IntoIterator<Item = S>) -> Self {
        self.artifact_subset = Some(names.into_iter().map(Into::into).collect());
        self
    }

    /// How the engines account time (default: `TimeMode::Virtual`, or
    /// `HETSTREAM_TIME=wallclock` from the environment).  Virtual mode
    /// runs the discrete-event clock — deterministic timelines, no
    /// real-time sleeping; wall-clock mode paces every op to its
    /// modeled duration like the original runtime.
    pub fn time_mode(mut self, mode: TimeMode) -> Self {
        self.time_mode = mode;
        self
    }

    /// Record a [`crate::device::TraceEntry`] per retired op, readable
    /// via [`Context::trace`] / [`Context::trace_json`].
    pub fn record_trace(mut self, on: bool) -> Self {
        self.record_trace = on;
        self
    }

    pub fn build(self) -> Result<Context> {
        let clock = Arc::new(SimClock::new(
            self.time_mode,
            self.compute_workers,
            self.record_trace,
        ));
        let arena = Arc::new(Mutex::new(DeviceArena::new(self.device_mem)));
        let dma = TransferEngine::new(arena.clone(), self.profile.clone(), clock.clone());
        let kex = ComputeEngine::new(
            arena.clone(),
            self.profile.clone(),
            self.artifacts_dir.clone(),
            self.compute_workers,
            self.artifact_subset.clone(),
            clock.clone(),
        );
        Ok(Context {
            arena,
            dma,
            kex,
            clock,
            profile: self.profile,
            next_stream: AtomicU64::new(0),
            next_op_seq: AtomicU64::new(0),
        })
    }
}

impl Default for ContextBuilder {
    fn default() -> Self {
        Self::new()
    }
}

/// The heterogeneous-platform handle: device memory plus the two engine
/// kinds every stream op is routed to, under one simulation clock.
pub struct Context {
    pub(crate) arena: Arc<Mutex<DeviceArena>>,
    pub(crate) dma: TransferEngine,
    pub(crate) kex: ComputeEngine,
    pub(crate) clock: Arc<SimClock>,
    profile: DeviceProfile,
    next_stream: AtomicU64,
    next_op_seq: AtomicU64,
}

impl Context {
    /// Shorthand: default builder.
    pub fn builder() -> ContextBuilder {
        ContextBuilder::new()
    }

    /// Create a new logical stream.
    pub fn stream(&self) -> Stream<'_> {
        let id = self.next_stream.fetch_add(1, Ordering::Relaxed);
        Stream::new(self, id)
    }

    /// The device profile this context models.
    pub fn profile(&self) -> &DeviceProfile {
        &self.profile
    }

    /// How this context accounts time.
    pub fn time_mode(&self) -> TimeMode {
        self.clock.mode()
    }

    /// Latest point any op has reached on the simulation timeline.
    pub fn now(&self) -> SimTime {
        self.clock.now()
    }

    /// Measurement-isolation barrier: align every modeled lane to the
    /// timeline horizon (virtual mode; no-op under wall clock).  Must
    /// only be called with the engines drained — after every submitted
    /// op has retired (e.g. right after the syncs that end a run).
    /// The plan executor behind [`crate::plan::SimBackend`] calls this
    /// on entry so each run's makespan is independent of what ran
    /// before it.
    pub fn quiesce_timeline(&self) {
        self.clock.quiesce();
    }

    /// The recorded op trace (submission order).  Empty unless the
    /// context was built with [`ContextBuilder::record_trace`].
    pub fn trace(&self) -> Vec<crate::device::TraceEntry> {
        self.clock.trace()
    }

    /// The recorded op trace as canonical JSON (golden-trace format).
    pub fn trace_json(&self) -> String {
        self.clock.trace_json()
    }

    /// Next context-wide op submission sequence (trace ordering).
    pub(crate) fn next_seq(&self) -> u64 {
        self.next_op_seq.fetch_add(1, Ordering::Relaxed)
    }

    /// Reserve a device buffer (lazy-alloc cost charged on first H2D).
    pub fn alloc(&self, len: usize) -> Result<BufId> {
        self.arena.lock().unwrap().alloc(len)
    }

    /// Release a device buffer.
    pub fn free(&self, id: BufId) -> Result<()> {
        self.arena.lock().unwrap().free(id)
    }

    /// Direct, un-timed read of device memory — for validation only.
    pub fn debug_read(&self, region: DevRegion) -> Result<Vec<u8>> {
        self.arena.lock().unwrap().read(region)
    }

    /// Bytes of device memory currently reserved.
    pub fn device_mem_used(&self) -> usize {
        self.arena.lock().unwrap().used()
    }
}
