//! E4 / Fig. 4: platform divergence — `nn`'s stage balance on the MIC
//! profile vs a K80-like profile.  The paper: KEX ≈ 33% on MIC but ≈ 2%
//! on the GPU, so streaming is pointless there.

use crate::corpus::configs_for;
use crate::device::DeviceProfile;
use crate::metrics::Table;

/// Analytic comparison across platform profiles (the engine path cannot
/// speed real compute up 16x, so Fig. 4 uses the stage model on both
/// profiles — see DESIGN.md §2).
pub fn fig4() -> Table {
    let mic = DeviceProfile::mic31sp();
    let k80 = DeviceProfile::k80();
    let mut t = Table::new(
        "Fig. 4 — R changes over platforms (Rodinia nn)",
        &["config", "MIC R_KEX", "K80 R_KEX", "MIC R_H2D", "K80 R_H2D"],
    );
    let mut mic_kex_sum = 0.0;
    let mut k80_kex_sum = 0.0;
    let cfgs = configs_for("nn");
    let n = cfgs.len() as f64;
    for cfg in &cfgs {
        let st_mic = super::analytic_stage_times(cfg, &mic);
        let st_k80 = super::analytic_stage_times(cfg, &k80);
        mic_kex_sum += st_mic.r_kex();
        k80_kex_sum += st_k80.r_kex();
        t.row(&[
            cfg.config.clone(),
            format!("{:.3}", st_mic.r_kex()),
            format!("{:.3}", st_k80.r_kex()),
            format!("{:.3}", st_mic.r_h2d()),
            format!("{:.3}", st_k80.r_h2d()),
        ]);
    }
    t.row(&[
        "MEAN".into(),
        format!("{:.3}", mic_kex_sum / n),
        format!("{:.3}", k80_kex_sum / n),
        String::new(),
        String::new(),
    ]);
    t
}
