//! E1 / Fig. 1: CDF of R_H2D and R_D2H over the 223-config corpus.

use crate::analysis::{fraction_at_or_below, OffloadSpec};
use crate::corpus::{all_configs, BenchConfig};
use crate::device::DeviceProfile;
use crate::hstreams::Context;
use crate::metrics::Table;

/// One corpus measurement.
#[derive(Debug, Clone)]
pub struct Fig1Row {
    pub app: &'static str,
    pub config: String,
    pub r_h2d: f64,
    pub r_d2h: f64,
}

/// Analytic sweep of the whole corpus (closed-form stage model).
pub fn fig1_analytic(profile: &DeviceProfile) -> (Table, Vec<Fig1Row>) {
    let rows: Vec<Fig1Row> = all_configs()
        .iter()
        .map(|c| {
            let st = super::analytic_stage_times(c, profile);
            Fig1Row { app: c.app, config: c.config.clone(), r_h2d: st.r_h2d(), r_d2h: st.r_d2h() }
        })
        .collect();
    (summarize(&rows), rows)
}

/// Engine sweep: every config measured stage-by-stage through the DMA +
/// compute engines (the paper's §3.3 protocol).  `runs` = repetitions
/// per config (paper: 11).
pub fn fig1_engine(
    ctx: &Context,
    runs: usize,
    subset: Option<usize>,
) -> (Table, Vec<Fig1Row>) {
    let mut configs = all_configs();
    if let Some(n) = subset {
        // Deterministic stratified subset: every k-th config.
        let step = (configs.len() / n.max(1)).max(1);
        configs = configs.into_iter().step_by(step).collect();
    }
    let rows: Vec<Fig1Row> = configs
        .iter()
        .map(|c| {
            let st = crate::analysis::measure_stages(ctx, &offload_spec(c), runs);
            Fig1Row { app: c.app, config: c.config.clone(), r_h2d: st.r_h2d(), r_d2h: st.r_d2h() }
        })
        .collect();
    (summarize(&rows), rows)
}

/// Map a corpus descriptor to a stage-measurable offload: lower it to
/// its bulk [`crate::plan::StreamPlan`] and read the spec off the IR's
/// op annotations (burner-backed KEX under the descriptor's FLOP
/// budget).
///
/// Bytes and FLOPs are scaled down by the engine time-dilation factor so
/// one engine-measured config costs about what the paper-scale analytic
/// model predicts; the linear stage terms cancel exactly, so R matches
/// the analytic model up to the (dilated) fixed latencies.  Iterative
/// kernels are capped at 20 repeats to keep the 223-config sweep
/// tractable (R for heavily iterative apps is then an upper bound on
/// R_H2D — they are non-streamable either way).  The scaling rules live
/// in [`crate::plan::lower_corpus_bulk`].
pub fn offload_spec(c: &BenchConfig) -> OffloadSpec {
    crate::plan::lower_corpus_bulk(c, "burner_64").offload_spec()
}

fn summarize(rows: &[Fig1Row]) -> Table {
    let h2d: Vec<f64> = rows.iter().map(|r| r.r_h2d).collect();
    let d2h: Vec<f64> = rows.iter().map(|r| r.r_d2h).collect();
    let mut t = Table::new(
        "Fig. 1 — CDF of data-transfer ratio R over the corpus",
        &["R threshold", "CDF(R_H2D <= x)", "CDF(R_D2H <= x)"],
    );
    for x in [0.05, 0.10, 0.20, 0.30, 0.50, 0.70, 0.90] {
        t.row(&[
            format!("{x:.2}"),
            format!("{:.1}%", 100.0 * fraction_at_or_below(&h2d, x)),
            format!("{:.1}%", 100.0 * fraction_at_or_below(&d2h, x)),
        ]);
    }
    t
}
