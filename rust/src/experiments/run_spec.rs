//! `repro run-spec FILE` — compile and execute a declarative
//! [`WorkloadSpec`] end-to-end (DESIGN.md §Spec).
//!
//! The spec-driven lowering path, exercised from a file: parse →
//! validate → [`SpecCompiler`] streamed lowering at the requested (or
//! spec-default) granularity → `StreamPlan::validate` plus the static
//! hazard verifier → execute on the chosen [`Backend`].  A plan with a
//! *fatal* hazard (anything beyond the strictness-only output-tiling
//! findings) is refused before anything runs, so the CLI exits
//! non-zero without touching an engine.  `--verify` additionally runs
//! the bulk lowering and demands bitwise-equal outputs — the paper's
//! §4 re-chunking oracle applied to a user spec.  `--json` emits the
//! lowered op list + totals in the `hetstream-run-spec-v1` schema that
//! `tools/mirror/tuner_mirror.py --spec-check` independently derives
//! and diffs in CI.

use crate::analysis::{
    autotune_plan_pruned, gran_ladder, normalize_ladder, predict_plan_point, Category,
};
use crate::hstreams::Context;
use crate::plan::{
    outputs_match, verify_plan, Backend, Granularity, PlanOpKind, PlanRegion, RunConfig, Slot,
    StreamPlan, VerifyReport,
};
use crate::spec::{category_token, SpecCompiler, WorkloadSpec};
use crate::util::json::escape;
use crate::{Error, Result};

/// Knobs of one `run-spec` invocation.
#[derive(Debug, Clone, Default)]
pub struct RunSpecOpts {
    /// Streams (engine lanes / native pool width) for the streamed run.
    pub streams: usize,
    /// Requested granularity; `None` = the spec's own default.  Either
    /// way the compiler's unified clamp applies on top.
    pub gran: Option<usize>,
    /// Also run the bulk lowering and demand bitwise-equal outputs.
    pub verify: bool,
}

/// Everything one run produced — the CLI report and the JSON dump.
#[derive(Debug)]
pub struct RunSpecOutcome {
    /// The streamed plan that executed.
    pub plan: StreamPlan,
    /// The static hazard verifier's report over that plan (sound by
    /// construction — fatal hazards are refused before execution).
    pub report: VerifyReport,
    /// Effective (post-clamp) granularity the plan was lowered at.
    pub gran: usize,
    pub streams: usize,
    pub backend: &'static str,
    pub wall_ms: f64,
    /// Assembled host outputs, one per plan output.
    pub outputs: Vec<Vec<u8>>,
    /// `Some(ok)` when the `--verify` bulk oracle ran.
    pub bulk_match: Option<bool>,
    /// `Some` when `--tune` routed the spec through the joint
    /// autotuner before running (the chosen knobs are then the run's
    /// own `streams`/`gran`).
    pub tuned: Option<SpecTune>,
}

/// What the joint autotuner chose for a spec (`repro run-spec --tune`).
#[derive(Debug, Clone)]
pub struct SpecTune {
    /// Winning stream count.
    pub streams: usize,
    /// Winning effective granularity.
    pub gran: usize,
    /// Modeled makespan at the winner, ms.
    pub best_ms: f64,
    /// Bulk (single-offload) reference makespan, ms.
    pub bulk_ms: f64,
    /// Grid points the pruned walk actually measured.
    pub points: usize,
}

/// Route a validated spec through the seeded pruned joint autotuner
/// (the PR-3/4 search, fed by the spec compiler's lowering): seed from
/// the analytic closed form ([`predict_plan_point`] over the bulk
/// plan, category-mapped into knob units), candidate axes from the
/// shared ladders, every candidate clamped through the compiler's
/// unified granularity clamp, measured under `ctx`'s virtual clock.
pub fn tune_spec(ctx: &Context, spec: &WorkloadSpec, runs: usize) -> Result<SpecTune> {
    spec.validate()?;
    let compiler = SpecCompiler::new(spec);
    let bulk = compiler.bulk();
    bulk.validate()?;
    let (seed_streams, seed_tasks) = predict_plan_point(&bulk, ctx.profile());
    // Task budget → knob units: wavefront categories spend it as a
    // grid side (same mapping as the service's `choose_plan`).
    let seed_gran = match spec.category {
        Category::TrueDependent => (seed_tasks as f64).sqrt().ceil() as usize,
        _ => seed_tasks,
    }
    .max(1);
    let seed_gran = compiler.effective_granularity(Granularity::new(seed_gran)).get();
    let streams = normalize_ladder(&[1, 2, 4, 8, seed_streams]);
    let mut grans: Vec<usize> = gran_ladder(seed_gran)
        .into_iter()
        .map(|g| compiler.effective_granularity(Granularity::new(g)).get())
        .collect();
    grans.sort_unstable();
    grans.dedup();
    let lower = |g: Granularity| compiler.streamed_at(compiler.effective_granularity(g));
    let r = autotune_plan_pruned(
        ctx,
        &bulk,
        &lower,
        &streams,
        &grans,
        (seed_streams, seed_gran),
        runs.max(1),
    )?;
    Ok(SpecTune {
        streams: r.best_streams,
        gran: r.best_gran,
        best_ms: r.best_ms,
        bulk_ms: r.bulk_ms,
        points: r.surface.len(),
    })
}

/// Lower `spec` at `gran` (spec default when `None`) and statically
/// check the result: `StreamPlan::validate` plus the hazard verifier.
/// A fatal hazard is a refusal ([`Error::Spec`], so the CLI exits
/// non-zero and nothing executes); strictness-only tiling findings are
/// carried in the report but do not block execution — `repro verify
/// --spec` demands full cleanliness separately.
pub fn compile_spec(
    spec: &WorkloadSpec,
    gran: Option<usize>,
) -> Result<(StreamPlan, VerifyReport, usize)> {
    spec.validate()?;
    let compiler = SpecCompiler::new(spec);
    let requested = Granularity::new(gran.unwrap_or(spec.granularity));
    let eff = compiler.effective_granularity(requested);
    let plan = compiler.streamed_at(eff);
    plan.validate()?;
    let report = verify_plan(&plan);
    if !report.is_sound() {
        let first = report
            .hazards
            .iter()
            .find(|h| h.kind.fatal())
            .map_or_else(|| "?".to_string(), |h| h.to_string());
        return Err(Error::Spec(format!(
            "spec `{}` lowers to a plan with a fatal hazard at granularity {}: {first}",
            spec.name,
            eff.get(),
        )));
    }
    Ok((plan, report, eff.get()))
}

/// Compile `spec` and execute it on `backend`; with `opts.verify`, run
/// the bulk lowering too and record whether the outputs match bitwise.
pub fn run_spec(
    spec: &WorkloadSpec,
    backend: &dyn Backend,
    opts: &RunSpecOpts,
) -> Result<RunSpecOutcome> {
    let (plan, report, gran) = compile_spec(spec, opts.gran)?;
    let run = backend.run(&plan, RunConfig::streams(opts.streams))?;
    let bulk_match = if opts.verify {
        let bulk = SpecCompiler::new(spec).bulk();
        bulk.validate()?;
        let oracle = backend.run(&bulk, RunConfig::streams(1))?;
        Some(outputs_match(&run, &oracle))
    } else {
        None
    };
    Ok(RunSpecOutcome {
        report,
        gran,
        streams: opts.streams.max(1),
        backend: backend.name(),
        wall_ms: run.wall.as_secs_f64() * 1e3,
        outputs: run.outputs,
        bulk_match,
        tuned: None,
        plan,
    })
}

/// FNV-1a over one output's assembled bytes (carried as a decimal
/// string in the JSON so f64-backed parsers cannot round it).
fn fnv64(data: &[u8]) -> u64 {
    data.iter().fold(0xCBF29CE484222325u64, |h, &b| {
        (h ^ b as u64).wrapping_mul(0x100000001B3)
    })
}

fn region_json(r: &PlanRegion) -> String {
    format!("{{\"buf\":{},\"off\":{},\"len\":{}}}", r.buf, r.off, r.len)
}

/// The run as one `hetstream-run-spec-v1` JSON document: the lowered
/// op list (kind / lane / regions / deps), plan totals, and the
/// output digests.  The Python mirror re-derives the op list from the
/// same spec file and diffs it against this dump in CI.
pub fn run_spec_json(spec: &WorkloadSpec, outcome: &RunSpecOutcome) -> String {
    let plan = &outcome.plan;
    let mut ops = String::new();
    for (i, op) in plan.ops.iter().enumerate() {
        if i > 0 {
            ops.push(',');
        }
        // Broadcast prologue ops carry lane -1; task ops their index.
        let slot = match op.slot {
            Slot::Broadcast => -1i64,
            Slot::Task(t) => t as i64,
        };
        let deps =
            op.deps.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(",");
        match &op.kind {
            PlanOpKind::H2d { src, dst } => ops.push_str(&format!(
                "{{\"kind\":\"h2d\",\"slot\":{slot},\"deps\":[{deps}],\
                 \"bytes\":{},\"buf\":{},\"off\":{}}}",
                src.len, dst.buf, dst.off
            )),
            PlanOpKind::Kex { artifact, inputs, outputs, flops, repeats } => {
                let regions = |rs: &[PlanRegion]| {
                    rs.iter().map(region_json).collect::<Vec<_>>().join(",")
                };
                ops.push_str(&format!(
                    "{{\"kind\":\"kex\",\"slot\":{slot},\"deps\":[{deps}],\
                     \"artifact\":\"{}\",\"inputs\":[{}],\"outputs\":[{}],\
                     \"flops\":{},\"repeats\":{}}}",
                    escape(artifact),
                    regions(inputs),
                    regions(outputs),
                    flops.map_or("null".to_string(), |f| f.to_string()),
                    repeats
                ));
            }
            PlanOpKind::D2h { src, output, off } => ops.push_str(&format!(
                "{{\"kind\":\"d2h\",\"slot\":{slot},\"deps\":[{deps}],\
                 \"bytes\":{},\"buf\":{},\"off\":{},\"output\":{output},\"out_off\":{off}}}",
                src.len, src.buf, src.off
            )),
        }
    }
    let outputs = outcome
        .outputs
        .iter()
        .map(|o| format!("{{\"bytes\":{},\"fnv64\":\"{}\"}}", o.len(), fnv64(o)))
        .collect::<Vec<_>>()
        .join(",");
    format!(
        "{{\"schema\":\"hetstream-run-spec-v1\",\"name\":\"{}\",\"category\":\"{}\",\
         \"mode\":\"{}\",\"gran\":{},\"streams\":{},\"backend\":\"{}\",\
         \"wall_ms\":{:.6},\"clean\":{},\"hazards\":{},\"bulk_match\":{},\"tuned\":{},\
         \"totals\":{{\"ops\":{},\"tasks\":{},\"bufs\":{},\"h2d_bytes\":{},\
         \"d2h_bytes\":{},\"kex_flops\":{}}},\"outputs\":[{outputs}],\"ops\":[{ops}]}}",
        escape(&spec.name),
        category_token(spec.category),
        spec.mode.token(),
        outcome.gran,
        outcome.streams,
        outcome.backend,
        outcome.wall_ms,
        outcome.report.is_clean(),
        outcome.report.hazards.len(),
        outcome.bulk_match.map_or("null".to_string(), |b| b.to_string()),
        outcome.tuned.as_ref().map_or("null".to_string(), |t| {
            format!(
                "{{\"streams\":{},\"gran\":{},\"best_ms\":{:.6},\"bulk_ms\":{:.6},\"points\":{}}}",
                t.streams, t.gran, t.best_ms, t.bulk_ms, t.points
            )
        }),
        plan.ops.len(),
        plan.tasks(),
        plan.bufs.len(),
        plan.h2d_bytes(),
        plan.d2h_bytes(),
        plan.kex_flops(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::NativeBackend;

    const DEMO: &str = r#"{
        "schema": "hetstream-spec-v1",
        "name": "rs-demo",
        "category": "independent",
        "mode": "windows",
        "granularity": 4,
        "output_bytes": 65536,
        "buffers": [
            {"name": "a", "bytes": 65536, "init": {"kind": "f32_rand", "seed": 7}},
            {"name": "b", "bytes": 65536, "init": {"kind": "f32_rand", "seed": 8}}
        ],
        "stages": [{"kernel": "vector_add", "inputs": ["a", "b"]}]
    }"#;

    #[test]
    fn run_spec_executes_and_passes_the_bulk_oracle() {
        let spec = WorkloadSpec::from_json(DEMO).expect("demo spec parses");
        let opts = RunSpecOpts { streams: 2, gran: None, verify: true };
        let outcome = run_spec(&spec, &NativeBackend::new(), &opts).expect("native run");
        assert_eq!(outcome.gran, 4);
        assert_eq!(outcome.backend, "native");
        assert_eq!(outcome.bulk_match, Some(true), "streamed must match bulk bitwise");
        assert_eq!(outcome.outputs.len(), 1);
        assert_eq!(outcome.outputs[0].len(), 65536);
        assert!(outcome.report.is_clean());
    }

    #[test]
    fn run_spec_json_parses_and_carries_the_op_list() {
        let spec = WorkloadSpec::from_json(DEMO).unwrap();
        let opts = RunSpecOpts { streams: 1, gran: Some(2), verify: false };
        let outcome = run_spec(&spec, &NativeBackend::new(), &opts).unwrap();
        assert_eq!(outcome.gran, 2);
        let doc = run_spec_json(&spec, &outcome);
        let v = crate::util::json::Json::parse(&doc).expect("valid JSON");
        assert_eq!(v.get("schema").and_then(|s| s.as_str()), Some("hetstream-run-spec-v1"));
        assert_eq!(v.get("gran").and_then(|n| n.as_usize()), Some(2));
        let ops = v.get("ops").and_then(|o| o.as_arr()).expect("ops array");
        assert_eq!(ops.len(), outcome.plan.ops.len());
        // 2 tasks x (2 uploads + 1 kex + 1 download).
        assert_eq!(ops.len(), 8);
        let kinds: Vec<&str> =
            ops.iter().filter_map(|o| o.get("kind").and_then(|k| k.as_str())).collect();
        assert_eq!(kinds.iter().filter(|k| **k == "kex").count(), 2);
        assert_eq!(
            v.get("totals").and_then(|t| t.get("d2h_bytes")).and_then(|n| n.as_usize()),
            Some(65536)
        );
    }

    #[test]
    fn tune_spec_picks_a_candidate_point_and_beats_bulk() {
        let spec = WorkloadSpec::from_json(DEMO).unwrap();
        let ctx = crate::hstreams::ContextBuilder::new()
            .profile(crate::device::DeviceProfile::mic31sp().simulation())
            .only_artifacts(vec!["vector_add"])
            .build()
            .expect("sim context");
        let tune = tune_spec(&ctx, &spec, 1).expect("tune");
        assert!(tune.streams >= 1);
        assert!(tune.gran >= 1, "gran must be a clamped knob value");
        assert!(tune.best_ms.is_finite() && tune.best_ms > 0.0);
        assert!(
            tune.best_ms <= tune.bulk_ms,
            "winner ({:.3} ms) must not lose to the bulk reference ({:.3} ms)",
            tune.best_ms,
            tune.bulk_ms
        );
        assert!(tune.points >= 1, "the pruned walk must measure at least the seed");
        // The chosen knobs drive a real run: lower at the winner and
        // dump — the JSON carries the tuned block verbatim.
        let outcome = run_spec(
            &spec,
            &NativeBackend::new(),
            &RunSpecOpts { streams: tune.streams, gran: Some(tune.gran), verify: true },
        )
        .map(|mut o| {
            o.tuned = Some(tune.clone());
            o
        })
        .expect("native run at the tuned point");
        assert_eq!(outcome.bulk_match, Some(true));
        let doc = run_spec_json(&spec, &outcome);
        let v = crate::util::json::Json::parse(&doc).expect("valid JSON");
        let t = v.get("tuned").expect("tuned block");
        assert_eq!(t.get("streams").and_then(|n| n.as_usize()), Some(tune.streams));
        assert_eq!(t.get("gran").and_then(|n| n.as_usize()), Some(tune.gran));
    }

    #[test]
    fn compile_spec_applies_the_unified_clamp() {
        let mut spec = WorkloadSpec::from_json(DEMO).unwrap();
        for b in &mut spec.buffers {
            b.bytes = 1024; // 256 f32 lanes
        }
        spec.output_bytes = 1024;
        // A huge granularity request clamps to one lane per task.
        let (plan, report, gran) = compile_spec(&spec, Some(1 << 40)).expect("compiles");
        assert_eq!(gran, 256);
        assert!(report.is_sound());
        assert!(plan.tasks() >= 1);
        // Malformed specs refuse cleanly before lowering.
        let mut bad = spec.clone();
        bad.stages[0].kernel = "no_such_kernel".into();
        assert!(matches!(compile_spec(&bad, None), Err(Error::Spec(_))));
    }
}
