//! `repro serve --demo N` — the multi-tenant serving demo: N
//! concurrent mixed-category corpus submissions through
//! [`crate::service::StreamService`], compared against serial
//! execution of the same submission set.
//!
//! The serial baseline is what every caller did before the service
//! existed: one engine, one submission at a time, policy + lowering
//! on the caller's critical path, no plan cache.  The service runs
//! the identical work — same policy, same descriptors, same virtual
//! clock physics — across its engine lanes with fair admission and a
//! shared plan cache, so the comparison isolates exactly what the API
//! redesign buys: wall-clock throughput (lanes overlap the real CPU
//! cost of simulating each run) and lowering reuse.  Every service
//! output is validated bitwise against its serial twin; modeled
//! makespans must agree too (quiesced lanes make the simulated
//! physics independent of scheduling).

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::corpus::BenchConfig;
use crate::device::{DeviceProfile, TimeMode};
use crate::hstreams::ContextBuilder;
use crate::metrics::{median_duration, Table};
use crate::plan::{
    lower_corpus_streamed_at, Backend, Granularity, NativeBackend, RunConfig, SimBackend,
    CORPUS_BURNER,
};
use crate::service::{
    AdaptiveConfig, AdaptiveStats, ExecBackend, Request, ServiceConfig, StreamService, TunePolicy,
};
use crate::{Error, Result};

use super::sweep::representative_configs;

/// How many distinct apps the demo roster cycles over (mixed
/// categories; submissions beyond this hit the plan cache).
const ROSTER_APPS: usize = 8;

/// Demo tenants submissions round-robin over.
const TENANTS: usize = 4;

/// Aggregate outcome of one serving demo.
#[derive(Debug, Clone)]
pub struct ServeSummary {
    pub submissions: usize,
    pub lanes: usize,
    /// The clock the demo ran under — decides which speedup below is
    /// the headline.
    pub time_mode: TimeMode,
    /// The execution backend the lanes (and the serial baseline) ran
    /// on.  On [`ExecBackend::Native`] every per-submission time is
    /// **real wall-clock execution** — there is no modeled physics —
    /// so the wall speedup is the headline regardless of `time_mode`.
    pub backend: ExecBackend,
    /// Wall-clock time for the service to drain every submission.
    /// Under [`TimeMode::Virtual`] this is **host simulation cost**
    /// (CPU scheduling noise), not modeled physics — report it as
    /// such, never as the headline.
    pub service_wall: Duration,
    /// Wall-clock time for the serial baseline over the same set
    /// (same caveat under the virtual clock).
    pub serial_wall: Duration,
    /// Aggregate wall throughput ratio, serial / service (>1 means the
    /// service outran serial execution of the same submissions).
    /// Meaningful as a headline only under [`TimeMode::Wallclock`].
    pub wall_speedup: f64,
    /// The virtual-clock headline: modeled time for one device to run
    /// the set serially (`Σ` modeled makespans) over the modeled time
    /// for the lane fleet to drain it (the busiest lane's total) —
    /// simulated physics, independent of host scheduling.
    pub modeled_speedup: f64,
    /// The busiest lane's modeled total, ms (the fleet's modeled drain
    /// time).
    pub modeled_drain_ms: f64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Sum of modeled makespans across submissions, ms.
    pub modeled_total_ms: f64,
    /// Every service output matched its serial twin bitwise, modeled
    /// times agreed (virtual mode), and no submission errored.
    pub validated: bool,
    pub errors: usize,
    /// Adaptive-runtime counters (`None` when `--adaptive` was off):
    /// batching, lane elasticity, and wakeup-mode distribution.
    pub adaptive: Option<AdaptiveStats>,
}

impl ServeSummary {
    /// The speedup to headline for this run's clock: modeled under
    /// [`TimeMode::Virtual`] (wall time there measures host scheduling
    /// noise, not the modeled system), wall under
    /// [`TimeMode::Wallclock`] — and always wall on the native
    /// backend, where every time is real execution.
    pub fn headline_speedup(&self) -> f64 {
        if self.backend == ExecBackend::Native {
            return self.wall_speedup;
        }
        match self.time_mode {
            TimeMode::Virtual => self.modeled_speedup,
            TimeMode::Wallclock => self.wall_speedup,
        }
    }
}

/// The demo submission set: the first [`ROSTER_APPS`] apps of a
/// category-interleaved ordering of the representative corpus — so
/// even a small demo spans independent / false-dependent / wavefront /
/// iterative / sync shapes — cycled to `n` submissions.
pub fn demo_roster(n: usize) -> Vec<BenchConfig> {
    let mut by_cat: Vec<(&'static str, Vec<BenchConfig>)> = Vec::new();
    for c in representative_configs(false) {
        let label = c.category().label();
        match by_cat.iter_mut().find(|(l, _)| *l == label) {
            Some((_, v)) => v.push(c),
            None => by_cat.push((label, vec![c])),
        }
    }
    let mut interleaved = Vec::new();
    let mut round = 0;
    while interleaved.len() < ROSTER_APPS {
        let mut any = false;
        for (_, v) in &by_cat {
            if let Some(c) = v.get(round) {
                interleaved.push(c.clone());
                any = true;
            }
        }
        if !any {
            break;
        }
        round += 1;
    }
    interleaved.truncate(ROSTER_APPS);
    (0..n).map(|i| interleaved[i % interleaved.len()].clone()).collect()
}

/// Run the serving demo: `n` submissions from [`TENANTS`] tenants onto
/// `lanes` engine lanes, vs a serial baseline on the same `backend`.
/// Returns the per-submission table and the aggregate summary.
pub fn serve_demo(
    profile: &DeviceProfile,
    time_mode: TimeMode,
    backend: ExecBackend,
    n: usize,
    lanes: usize,
    runs: usize,
    policy: Arc<dyn TunePolicy>,
    adaptive: Option<AdaptiveConfig>,
) -> Result<(Table, ServeSummary)> {
    if n == 0 {
        return Err(Error::Config("serve demo needs --demo N >= 1".into()));
    }
    let runs = runs.max(1);
    let roster = demo_roster(n);

    // --- serial baseline: one executor, submissions one at a time ---
    // Matched to the service's backend so the wall comparison is
    // apples-to-apples (sim vs sim, or real execution vs real
    // execution).
    let ctx;
    let serial_exec: Box<dyn Backend + '_> = match backend {
        ExecBackend::Sim => {
            ctx = ContextBuilder::new()
                .profile(profile.clone())
                .time_mode(time_mode)
                .only_artifacts(vec![CORPUS_BURNER])
                .build()?;
            Box::new(SimBackend::new(&ctx))
        }
        ExecBackend::Native => Box::new(NativeBackend::new()),
    };
    let serial_t0 = Instant::now();
    let mut serial: Vec<(f64, Vec<Vec<u8>>)> = Vec::with_capacity(n);
    for c in &roster {
        let choice = policy.choose(c, profile);
        let plan = lower_corpus_streamed_at(c, CORPUS_BURNER, Granularity::new(choice.gran));
        let mut samples = Vec::with_capacity(runs);
        let mut outputs = Vec::new();
        for rep in 0..runs {
            let run = serial_exec.run(&plan, RunConfig::streams(choice.streams))?;
            samples.push(run.wall);
            if rep == 0 {
                outputs = run.outputs;
            }
        }
        serial.push((median_duration(&mut samples).as_secs_f64() * 1e3, outputs));
    }
    let serial_wall = serial_t0.elapsed();

    // --- the service: same submissions, concurrent ------------------
    let service = StreamService::start(
        ServiceConfig {
            lanes,
            runs,
            profile: profile.clone(),
            time_mode,
            backend,
            artifacts: Some(vec![CORPUS_BURNER.into()]),
            // The demo is closed-loop over a fixed roster — admission
            // control is the load harness's concern (`repro bench`).
            admission: None,
            adaptive,
        },
        policy,
    )?;
    let service_t0 = Instant::now();
    let tickets: Vec<_> = roster
        .iter()
        .enumerate()
        .map(|(i, c)| {
            service.submit(&format!("tenant-{}", i % TENANTS), Request::Corpus(c.clone()))
        })
        .collect::<Result<_>>()?;
    let reports: Vec<_> = tickets.into_iter().map(|t| t.wait()).collect::<Result<_>>()?;
    let service_wall = service_t0.elapsed();
    let stats = service.shutdown();

    // --- per-submission table + bitwise validation ------------------
    let mut t = Table::new(
        format!(
            "Serving demo — {n} submissions, {lanes} lanes, {} backend, policy-tuned",
            backend.label()
        ),
        &[
            "#",
            "tenant",
            "app",
            "category",
            "(s,g)",
            "policy",
            "lane",
            "cache",
            // On native lanes the per-job time is real execution.
            if backend == ExecBackend::Native { "wall (ms)" } else { "modeled (ms)" },
            "valid",
        ],
    );
    let mut validated = true;
    let mut errors = 0usize;
    for (i, r) in reports.iter().enumerate() {
        let (serial_ms, serial_outputs) = &serial[i];
        // Bitwise: the service must hand back exactly the bytes the
        // serial twin produced; under the virtual clock the modeled
        // makespan must agree too (quiesced-lane determinism — sim
        // only; native times are real wall clock and vary run to run).
        let mut ok = r.ok() && r.outputs == *serial_outputs;
        if backend == ExecBackend::Sim && time_mode == TimeMode::Virtual {
            ok &= r.modeled_ms == *serial_ms;
        }
        validated &= ok;
        errors += usize::from(!r.ok());
        t.row(&[
            i.to_string(),
            r.tenant.clone(),
            r.name.clone(),
            r.category.unwrap_or("-").to_string(),
            match r.gran {
                Some(g) => format!("({}, {g})", r.streams),
                None => format!("({}, -)", r.streams),
            },
            if r.learned { "learned".into() } else { "analytic".to_string() },
            r.lane.to_string(),
            if r.cache_hit { "hit".into() } else { "miss".to_string() },
            if r.modeled_ms.is_finite() { format!("{:.2}", r.modeled_ms) } else { "-".into() },
            match &r.error {
                Some(e) => format!("FAIL: {e}"),
                None => ok.to_string(),
            },
        ]);
    }

    let wall_speedup = if service_wall.as_secs_f64() > 0.0 {
        serial_wall.as_secs_f64() / service_wall.as_secs_f64()
    } else {
        f64::NAN
    };
    // Modeled headline: one device running the set serially (the sum
    // of modeled makespans) vs the lane fleet draining it (the busiest
    // lane's total) — pure simulated physics.  The wall numbers above
    // measure the host CPU cost of *simulating* under the virtual
    // clock, which is scheduling noise, not the modeled system.
    let modeled_total_ms: f64 = reports.iter().filter(|r| r.ok()).map(|r| r.modeled_ms).sum();
    let modeled_drain_ms = stats.modeled_drain_ms();
    let modeled_speedup =
        if modeled_drain_ms > 0.0 { modeled_total_ms / modeled_drain_ms } else { f64::NAN };
    let summary = ServeSummary {
        submissions: n,
        lanes,
        time_mode,
        backend,
        service_wall,
        serial_wall,
        wall_speedup,
        modeled_speedup,
        modeled_drain_ms,
        cache_hits: stats.cache_hits,
        cache_misses: stats.cache_misses,
        modeled_total_ms,
        validated,
        errors,
        adaptive: stats.adaptive,
    };
    Ok((t, summary))
}
