//! E6 / Fig. 9: single-stream vs multi-stream wall-clock for the 13
//! streamed benchmarks, plus the E8 R-vs-gain correlation.

use crate::hstreams::Context;
use crate::metrics::{median_duration, Table};
use crate::workloads::{fig9_benchmarks, Benchmark, Mode};
use crate::Result;

/// One benchmark's measurement.
#[derive(Debug, Clone)]
pub struct Fig9Row {
    pub name: String,
    pub baseline_ms: f64,
    pub streamed_ms: f64,
    /// Paper's metric: (t_single / t_multi - 1) * 100.
    pub improvement_pct: f64,
    pub h2d_baseline: u64,
    pub h2d_streamed: u64,
    pub validated: bool,
}

/// Run one benchmark in both modes, `runs`-median each.
pub fn measure_one(
    ctx: &Context,
    b: &dyn Benchmark,
    streams: usize,
    runs: usize,
) -> Result<Fig9Row> {
    let mut base_samples = Vec::with_capacity(runs);
    let mut strm_samples = Vec::with_capacity(runs);
    let mut h2d_b = 0;
    let mut h2d_s = 0;
    let mut validated = true;
    // Warmup: absorb PJRT first-execution costs outside the samples.
    b.run(ctx, Mode::Baseline)?;
    for _ in 0..runs {
        let rb = b.run(ctx, Mode::Baseline)?;
        validated &= rb.validated;
        h2d_b = rb.h2d_bytes;
        base_samples.push(rb.wall);
        let rs = b.run(ctx, Mode::Streamed(streams))?;
        validated &= rs.validated;
        h2d_s = rs.h2d_bytes;
        strm_samples.push(rs.wall);
    }
    let base = median_duration(&mut base_samples).as_secs_f64() * 1e3;
    let strm = median_duration(&mut strm_samples).as_secs_f64() * 1e3;
    // Shared guard (`util::improvement_pct`, same rule as the corpus
    // tuner): an instant-profile run (strm = 0) must report "no
    // measurable improvement", not walk inf/NaN into the table.
    let improvement_pct = crate::util::improvement_pct(base, strm);
    Ok(Fig9Row {
        name: b.name().into(),
        baseline_ms: base,
        streamed_ms: strm,
        improvement_pct,
        h2d_baseline: h2d_b,
        h2d_streamed: h2d_s,
        validated,
    })
}

/// The full Fig. 9 sweep.
pub fn fig9(
    ctx: &Context,
    scale: usize,
    streams: usize,
    runs: usize,
) -> Result<(Table, Vec<Fig9Row>)> {
    let mut rows = Vec::new();
    for b in fig9_benchmarks(scale) {
        rows.push(measure_one(ctx, b.as_ref(), streams, runs)?);
    }
    let mut t = Table::new(
        format!("Fig. 9 — single vs {streams} streams (scale {scale})"),
        &["benchmark", "single (ms)", "multi (ms)", "improvement", "h2d xfer ratio", "valid"],
    );
    for r in &rows {
        t.row(&[
            r.name.clone(),
            format!("{:.2}", r.baseline_ms),
            format!("{:.2}", r.streamed_ms),
            if r.improvement_pct.is_finite() {
                format!("{:+.1}%", r.improvement_pct)
            } else {
                "-".into()
            },
            format!("{:.2}x", r.h2d_streamed as f64 / r.h2d_baseline.max(1) as f64),
            r.validated.to_string(),
        ]);
    }
    Ok((t, rows))
}

/// E8: R vs gain for ConvolutionSeparable and Transpose (paper §5: a
/// larger R leads to a greater improvement).
pub fn rgain(ctx: &Context, scale: usize, streams: usize, runs: usize) -> Result<Table> {
    use crate::workloads::{ConvSep, Transpose};
    let mut t = Table::new(
        "§5 — R vs streaming gain (ConvSep vs Transpose)",
        &["benchmark", "scale", "R_H2D", "improvement"],
    );
    for s in [scale, scale * 2] {
        let benches: Vec<(Box<dyn Benchmark>, &str)> = vec![
            (Box::new(ConvSep::new(s)), "ConvolutionSeparable"),
            (Box::new(Transpose::new(s)), "Transpose"),
        ];
        for (b, name) in benches {
            let row = measure_one(ctx, b.as_ref(), streams, runs)?;
            // R from the corpus stage model at this profile.
            let cfg = &crate::corpus::configs_for(name)[0];
            let st = super::analytic_stage_times(cfg, ctx.profile());
            t.row(&[
                name.to_string(),
                format!("{s}"),
                format!("{:.2}", st.r_h2d()),
                format!("{:+.1}%", row.improvement_pct),
            ]);
        }
    }
    Ok(t)
}
