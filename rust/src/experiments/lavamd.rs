//! E7 / §5: the lavaMD negative result — halo ≈ task size means the
//! streamed port transfers ~1.9x the bytes in many tiny DMAs and loses
//! to the bulk offload.

use crate::hstreams::Context;
use crate::metrics::Table;
use crate::partition::halo_overhead_ratio;
use crate::workloads::LavaMd;
use crate::Result;

/// Reproduce the §5 lavaMD numbers: single-stream H2D/KEX vs streamed
/// total, plus the halo-overhead analysis that predicts the loss.
pub fn lavamd_negative(ctx: &Context, scale: usize, streams: usize, runs: usize) -> Result<Table> {
    let b = LavaMd::new(scale);
    let row = super::fig9::measure_one(ctx, &b, streams, runs)?;
    let ratio =
        halo_overhead_ratio(crate::workloads::lavamd::CHUNK, crate::workloads::lavamd::HALO);

    let mut t = Table::new(
        "§5 — lavaMD negative case",
        &["metric", "value"],
    );
    t.row(&["halo/task ratio (paper: 222/250 ≈ 0.89)", &format!("{ratio:.2}")]);
    t.row(&["bulk offload (ms)", &format!("{:.2}", row.baseline_ms)]);
    t.row(&[&format!("streamed x{streams} (ms)"), &format!("{:.2}", row.streamed_ms)]);
    t.row(&["improvement", &format!("{:+.1}%", row.improvement_pct)]);
    t.row(&[
        "h2d bytes streamed/bulk (paper: ~1.9x)",
        &format!("{:.2}x", row.h2d_streamed as f64 / row.h2d_baseline.max(1) as f64),
    ]);
    t.row(&["validated", &row.validated.to_string()]);
    Ok(t)
}
