//! E5 / Table 2: benchmark categorization from dependency facts.

use crate::analysis::Category;
use crate::corpus::{apps, Suite};
use crate::metrics::Table;

/// Regenerate Table 2: one row per suite, apps grouped by category.
pub fn table2() -> Table {
    let mut t = Table::new(
        "Table 2 — Application categorization",
        &["Suite", "Independent", "False-dependent", "True-dependent", "SYNC", "Iterative"],
    );
    for suite in [Suite::Rodinia, Suite::Parboil, Suite::NvidiaSdk, Suite::AmdSdk] {
        let cell = |cat: Category| -> String {
            let mut names: Vec<&str> = apps()
                .into_iter()
                .filter(|(_, s, c)| *s == suite && *c == cat)
                .map(|(a, _, _)| a)
                .collect();
            names.sort();
            names.join(", ")
        };
        t.row(&[
            suite.label().to_string(),
            cell(Category::Independent),
            cell(Category::FalseDependent),
            cell(Category::TrueDependent),
            cell(Category::Sync),
            cell(Category::Iterative),
        ]);
    }
    t
}
