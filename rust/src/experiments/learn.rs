//! `repro learn` — train and evaluate the learned (streams ×
//! granularity) tuner over the corpus (`analysis::learned`).
//!
//! Two modes:
//!
//! - **summary** (default): build the training set — from a `repro
//!   tune --corpus --json` dump when `--dataset PATH` is given, else by
//!   running the exhaustive tuner in-process — and print the labeled
//!   rows plus the feature-space vocabulary.
//! - **`--cv`**: leave-one-app-out cross-validation.  For each corpus
//!   app: train the k-NN on every *other* app, predict this app's
//!   `(streams, granularity)`, snap the prediction onto the app's
//!   measured candidate grid, and compare its measured time against
//!   the exhaustive-grid optimum.  The aggregate "within 10%" rate is
//!   the headline number (`tests/learned_integration.rs` asserts
//!   ≥ 80% over the full corpus; CI smokes a subset).

use crate::analysis::{corpus_features, snap_seed, Dataset, KnnTuner, TrainRow, FEATURE_NAMES};
use crate::corpus::{all_configs, BenchConfig};
use crate::hstreams::Context;
use crate::metrics::Table;
use crate::Result;

use super::sweep::{representative_configs, tune_configs, TuneRow, TuneStrategy};

/// Convert measured tuning rows into training rows (validated rows
/// only — error rows carry placeholder optima, not labels).
pub fn dataset_from_tune_rows(rows: &[TuneRow], ctx: &Context) -> Dataset {
    let configs = all_configs();
    let rows = rows
        .iter()
        .filter(|r| r.validated && r.error.is_none())
        .filter_map(|r| {
            let c = configs
                .iter()
                .find(|c| c.app == r.app && c.config == r.config && c.suite.label() == r.suite)?;
            Some(TrainRow {
                suite: r.suite.into(),
                app: r.app.into(),
                config: r.config.clone(),
                features: corpus_features(c, ctx.profile()),
                best_streams: r.best_streams,
                best_gran: r.best_gran,
            })
        })
        .collect();
    Dataset { rows }
}

/// Render the training set (one labeled feature row per app).
pub fn dataset_table(ds: &Dataset) -> Table {
    let mut t = Table::new(
        format!(
            "Learned-tuner training set — {} rows over features [{}]",
            ds.rows.len(),
            FEATURE_NAMES.join(", ")
        ),
        &["suite", "app", "config", "category", "best (s,g)", "features"],
    );
    for r in &ds.rows {
        t.row(&[
            r.suite.clone(),
            r.app.clone(),
            r.config.clone(),
            format!("{:?}", r.features.category),
            format!("({}, {})", r.best_streams, r.best_gran),
            r.features.values.iter().map(|v| format!("{v:.3}")).collect::<Vec<_>>().join(" "),
        ]);
    }
    t
}

/// Aggregate outcome of a leave-one-app-out cross-validation run.
#[derive(Debug, Clone, Copy)]
pub struct CvStats {
    /// Apps evaluated (tuned successfully).
    pub apps: usize,
    /// Apps whose predicted point measured within 10% of the optimum.
    pub within_10pct: usize,
    /// Predictions that came from the k-NN (vs analytic fallback).
    pub learned: usize,
    /// Apps whose exhaustive tuning failed (excluded from `apps`) —
    /// CI gates on this being zero.
    pub failures: usize,
}

impl CvStats {
    pub fn within_fraction(&self) -> f64 {
        if self.apps == 0 {
            return 0.0;
        }
        self.within_10pct as f64 / self.apps as f64
    }
}

/// Leave-one-app-out CV over the first `subset` representative corpus
/// apps (0 = all 56).  `external` supplies training labels from a
/// `--dataset` file; the held-out app's surface is always measured
/// in-process (training labels may come from elsewhere, but the
/// evaluation must compare measured times under *this* context).
pub fn learn_cv(
    ctx: &Context,
    streams: &[usize],
    grans: &[usize],
    subset: usize,
    k: usize,
    external: Option<&Dataset>,
) -> Result<(Table, CvStats)> {
    let mut configs = representative_configs(false);
    if subset > 0 {
        configs.truncate(subset);
    }
    let rows = tune_configs(ctx, &configs, streams, grans, 1, TuneStrategy::Exhaustive);
    let dataset = match external {
        Some(ds) => ds.clone(),
        None => dataset_from_tune_rows(&rows, ctx),
    };
    let model = KnnTuner::fit(dataset, k.max(1));

    let mut t = Table::new(
        format!("Leave-one-app-out CV — k = {}, {} apps", k.max(1), rows.len()),
        &["suite", "app", "category", "seed", "predicted (s,g)", "pred (ms)", "best (s,g)",
          "best (ms)", "overhead", "within 10%"],
    );
    let mut stats = CvStats { apps: 0, within_10pct: 0, learned: 0, failures: 0 };
    for (c, r) in configs.iter().zip(&rows) {
        if !r.validated || r.error.is_some() {
            stats.failures += 1;
            t.row(&[
                r.suite.to_string(),
                r.app.to_string(),
                r.category.to_string(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                format!("FAIL: {}", r.error.as_deref().unwrap_or("invalid")),
            ]);
            continue;
        }
        // Candidate axes this row actually measured (effective knob
        // values — recovered from the surface so file-trained CV uses
        // the same snapping as in-process CV).
        let mut srow: Vec<usize> = r.surface.iter().map(|&(n, _, _)| n).collect();
        srow.sort_unstable();
        srow.dedup();
        let mut grow: Vec<usize> = r.surface.iter().map(|&(_, g, _)| g).collect();
        grow.sort_unstable();
        grow.dedup();

        let held_out = model.without_app(r.app);
        let pred = held_out.predict(&corpus_features(c, ctx.profile()));
        let learned = pred.is_some();
        // Analytic fallback on an empty neighborhood: the row's seed is
        // the analytic point under the exhaustive strategy.
        let (snap_s, snap_g) = snap_seed(&srow, &grow, pred.unwrap_or(r.seed));
        let pred_ms = r
            .surface
            .iter()
            .find(|&&(n, g, _)| n == snap_s && g == snap_g)
            .map(|&(_, _, ms)| ms)
            .unwrap_or(f64::NAN);
        // A degenerate zero-time optimum (instant profile) is unknown,
        // not a pass — never fabricate a "within 10%" from it.
        let ratio = if r.best_ms > 0.0 { pred_ms / r.best_ms } else { f64::NAN };
        let within = ratio.is_finite() && ratio <= 1.10;
        stats.apps += 1;
        stats.within_10pct += usize::from(within);
        stats.learned += usize::from(learned);
        t.row(&[
            r.suite.to_string(),
            r.app.to_string(),
            r.category.to_string(),
            if learned { "knn".into() } else { "analytic".to_string() },
            format!("({snap_s}, {snap_g})"),
            format!("{pred_ms:.2}"),
            format!("({}, {})", r.best_streams, r.best_gran),
            format!("{:.2}", r.best_ms),
            if ratio.is_finite() { format!("{:+.1}%", (ratio - 1.0) * 100.0) } else { "-".into() },
            within.to_string(),
        ]);
    }
    Ok((t, stats))
}

/// Build the training set without CV: load `--dataset` text, or tune
/// the (subset of the) corpus exhaustively in-process.  `DEFAULT_K` is
/// the model's neighborhood unless the caller overrides it.
pub fn learn_dataset(
    ctx: &Context,
    streams: &[usize],
    grans: &[usize],
    subset: usize,
    dataset_json: Option<&str>,
) -> Result<Dataset> {
    if let Some(text) = dataset_json {
        return Dataset::from_tune_json(text, ctx.profile());
    }
    let mut configs = representative_configs(false);
    if subset > 0 {
        configs.truncate(subset);
    }
    let rows = tune_configs(ctx, &configs, streams, grans, 1, TuneStrategy::Exhaustive);
    Ok(dataset_from_tune_rows(&rows, ctx))
}

/// Tune one descriptor with a pruned walk seeded by `model` — the
/// leave-one-app-out harness's inner step (`tests/learned_integration`
/// holds each app out and compares against its exhaustive row).
pub fn tune_held_out(
    ctx: &Context,
    c: &BenchConfig,
    streams: &[usize],
    grans: &[usize],
    model: &KnnTuner,
) -> TuneRow {
    tune_configs(
        ctx,
        std::slice::from_ref(c),
        streams,
        grans,
        1,
        TuneStrategy::Pruned { model: Some(model) },
    )
    .remove(0)
}
