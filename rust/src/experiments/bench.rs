//! `repro bench` — the multi-tenant load harness over
//! [`crate::service::StreamService`] (DESIGN.md §Bench).
//!
//! The serving demo (`repro serve --demo N`) is closed-loop over a
//! fixed roster; a serving system is judged under *load*: sustained
//! arrival rates, tenants that misbehave, latency tails.  This module
//! is the BenchRunner-style generator that produces those numbers —
//! one worker thread per tenant paces mixed-category corpus
//! submissions at a target rate (closed-loop: wait for each result
//! before pacing the next; `--open-loop`: submit on schedule no matter
//! what's in flight), every outcome becomes a timestamped event, and
//! the reporter merges the per-worker event streams into a per-second
//! time series (throughput + avg/p50/p99 end-to-end latency + queue
//! wait) emitted as the `BENCH_<timestamp>.json` artifact
//! ([`crate::metrics::bench_json`]) so service performance is
//! comparable across PRs.
//!
//! Combined with cost-based admission
//! ([`crate::service::AdmissionConfig`]), this is where load shedding
//! becomes observable: an open-loop flooding tenant overruns its
//! modeled-ms budget and is shed at submit, while a well-behaved
//! tenant's latency tail stays bounded
//! (`tests/bench_integration.rs` asserts exactly that).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::device::{DeviceProfile, TimeMode};
use crate::metrics::{latency_stats, BenchReport, BenchTick, Table, TenantTotals};
use crate::service::{
    AdaptiveConfig, AdmissionConfig, ExecBackend, Request, ServiceConfig, StreamService, Ticket,
    TunePolicy,
};
use crate::util::percentile;
use crate::{Error, Result};

use super::serve::demo_roster;

/// Apps in the bench submission mix (the category-interleaved serve
/// roster — every Table-2 shape appears in the load).
const BENCH_ROSTER_APPS: usize = 8;

/// Load-harness configuration (`repro bench` flags).
#[derive(Clone)]
pub struct BenchOpts {
    /// Worker threads, one per tenant.
    pub tenants: usize,
    /// Target submission rate per tenant, req/s.
    pub rate: f64,
    /// Submission-window length, s (completions drain past it).
    pub secs: f64,
    /// Submit on schedule without waiting for completions.
    pub open_loop: bool,
    /// Service engine lanes.
    pub lanes: usize,
    /// Optional misbehaving tenant: `(index, rate multiplier)` —
    /// tenant `index` submits at `rate × multiplier`.
    pub flood: Option<(usize, f64)>,
    /// Cost-based admission (None = admit everything).
    pub admission: Option<AdmissionConfig>,
    pub profile: DeviceProfile,
    pub time_mode: TimeMode,
    /// Lane execution backend; on [`ExecBackend::Native`] the latency
    /// numbers are real host execution, not simulation cost.
    pub backend: ExecBackend,
    /// Adaptive service runtime (`--adaptive`): `lanes` becomes the
    /// initial fleet and the controller batches / grows / parks from
    /// the measured window.
    pub adaptive: Option<AdaptiveConfig>,
}

/// One submission outcome, stamped with its completion (or shed) time
/// relative to the bench epoch.
struct Event {
    tenant: usize,
    /// Seconds since the bench epoch at completion/shed.
    t_s: f64,
    kind: EventKind,
}

enum EventKind {
    Done { e2e_ms: f64, queue_ms: f64 },
    Shed,
    Error,
}

/// Drive the load: spawn one worker per tenant, pace submissions,
/// merge the per-worker event streams into the per-second series.
pub fn run_bench(opts: &BenchOpts, policy: Arc<dyn TunePolicy>) -> Result<BenchReport> {
    if opts.tenants == 0 || opts.rate <= 0.0 || opts.secs <= 0.0 {
        return Err(Error::Config(
            "bench needs --tenants >= 1, --rate > 0 and --secs > 0".into(),
        ));
    }
    let roster = demo_roster(BENCH_ROSTER_APPS);
    let service = StreamService::start(
        ServiceConfig {
            lanes: opts.lanes.max(1),
            runs: 1,
            profile: opts.profile.clone(),
            time_mode: opts.time_mode,
            backend: opts.backend,
            artifacts: Some(vec![crate::plan::CORPUS_BURNER.into()]),
            admission: opts.admission,
            adaptive: opts.adaptive,
        },
        policy,
    )?;

    let epoch = Instant::now();
    // Live counters for the progress reporter (the exact series is
    // rebuilt from the timestamped events afterwards).
    let live_done = AtomicU64::new(0);
    let live_shed = AtomicU64::new(0);
    let stop_reporter = AtomicU64::new(0);

    let events: Vec<Event> = std::thread::scope(|s| {
        let service = &service;
        let roster = &roster;
        let (live_done, live_shed, stop) = (&live_done, &live_shed, &stop_reporter);
        // Progress ticker: one stderr line per second while the load
        // runs — observability, not measurement.
        s.spawn(move || {
            let mut tick = 0u64;
            while stop.load(Ordering::Relaxed) == 0 {
                std::thread::sleep(Duration::from_millis(1000));
                tick += 1;
                eprintln!(
                    "bench t={tick}s: {} completed, {} shed, {} pending",
                    live_done.load(Ordering::Relaxed),
                    live_shed.load(Ordering::Relaxed),
                    service.pending(),
                );
            }
        });
        let workers: Vec<_> = (0..opts.tenants)
            .map(|tenant| {
                s.spawn(move || {
                    worker_loop(tenant, opts, service, roster, epoch, live_done, live_shed)
                })
            })
            .collect();
        let merged: Vec<Event> =
            workers.into_iter().flat_map(|w| w.join().expect("bench worker")).collect();
        stop.store(1, Ordering::Relaxed);
        merged
    });
    let stats = service.shutdown();

    // --- the reporter merge: events → per-second series + totals ----
    let mut ticks = ticks_from_events(&events);
    // Ticks are one second wide, so per-tick throughput = completions.
    for t in &mut ticks {
        t.throughput_rps = t.completed as f64;
    }
    // Merge the adaptive controller's per-second log (mode / lane
    // target / batch count) into the series: exact match by tick
    // index, forward-filling mode and lanes across seconds the
    // controller logged nothing for.  The controller's epoch is the
    // service start, microseconds before the bench epoch — well under
    // the one-second tick width.  Without the adaptive runtime every
    // tick reads park / fixed lanes / zero batches.
    let mut mode = crate::service::WakeupMode::Park.label().to_string();
    let mut lanes_now = opts.lanes.max(1) as u64;
    for t in &mut ticks {
        if let Some(a) = stats.adaptive_ticks.iter().find(|a| a.t_s == t.t_s) {
            mode = a.mode.label().to_string();
            lanes_now = a.lanes as u64;
            t.batches = a.batches;
        }
        t.mode = mode.clone();
        t.lanes = lanes_now;
    }

    let done: Vec<&Event> =
        events.iter().filter(|e| matches!(e.kind, EventKind::Done { .. })).collect();
    let e2e: Vec<f64> = done
        .iter()
        .map(|e| match e.kind {
            EventKind::Done { e2e_ms, .. } => e2e_ms,
            _ => unreachable!(),
        })
        .collect();
    let queue: Vec<f64> = done
        .iter()
        .map(|e| match e.kind {
            EventKind::Done { queue_ms, .. } => queue_ms,
            _ => unreachable!(),
        })
        .collect();
    let (lat_avg_ms, lat_p50_ms, lat_p99_ms) = latency_stats(&e2e);
    let (queue_avg_ms, _, _) = latency_stats(&queue);
    let duration_s = events.iter().map(|e| e.t_s).fold(opts.secs, f64::max);

    let mut per_tenant = Vec::with_capacity(opts.tenants);
    for tenant in 0..opts.tenants {
        let name = tenant_name(tenant);
        let mine: Vec<&Event> = events.iter().filter(|e| e.tenant == tenant).collect();
        let lat: Vec<f64> = mine
            .iter()
            .filter_map(|e| match e.kind {
                EventKind::Done { e2e_ms, .. } => Some(e2e_ms),
                _ => None,
            })
            .collect();
        // Worker-observed sheds must agree with the service's own
        // accounting; trust the events (they're per-tenant exact) and
        // cross-check in tests.
        per_tenant.push(TenantTotals {
            tenant: name,
            completed: lat.len() as u64,
            shed: mine.iter().filter(|e| matches!(e.kind, EventKind::Shed)).count() as u64,
            errors: mine.iter().filter(|e| matches!(e.kind, EventKind::Error)).count() as u64,
            p99_ms: percentile(&lat, 99.0),
        });
    }

    let completed = done.len() as u64;
    let rejected = events.iter().filter(|e| matches!(e.kind, EventKind::Shed)).count() as u64;
    let errors = events.iter().filter(|e| matches!(e.kind, EventKind::Error)).count() as u64;
    Ok(BenchReport {
        tenants: opts.tenants,
        rate: opts.rate,
        secs: opts.secs,
        open_loop: opts.open_loop,
        lanes: opts.lanes.max(1),
        adaptive: opts.adaptive.is_some(),
        max_lanes: opts
            .adaptive
            .map(|a| a.normalized().max_lanes)
            .unwrap_or(opts.lanes.max(1)),
        profile: opts.profile.name.clone(),
        time_mode: format!("{:?}", opts.time_mode).to_lowercase(),
        backend: opts.backend.label().into(),
        ticks,
        per_tenant,
        completed,
        rejected,
        errors,
        duration_s,
        throughput_rps: if duration_s > 0.0 { completed as f64 / duration_s } else { f64::NAN },
        lat_avg_ms,
        lat_p50_ms,
        lat_p99_ms,
        queue_avg_ms,
        modeled_total_ms: stats.modeled_ms(),
        cache_hits: stats.cache_hits,
        cache_misses: stats.cache_misses,
        batches: stats.adaptive.as_ref().map(|a| a.batches).unwrap_or(0),
        batched_jobs: stats.adaptive.as_ref().map(|a| a.batched_jobs).unwrap_or(0),
        lane_grows: stats.adaptive.as_ref().map(|a| a.lane_grows).unwrap_or(0),
        lane_retires: stats.adaptive.as_ref().map(|a| a.lane_retires).unwrap_or(0),
        wakeup_switches: stats.adaptive.as_ref().map(|a| a.wakeup_switches).unwrap_or(0),
        peak_lanes: stats
            .adaptive
            .as_ref()
            .map(|a| a.peak_lanes)
            .unwrap_or(opts.lanes.max(1) as u64),
    })
}

fn tenant_name(tenant: usize) -> String {
    format!("tenant-{tenant}")
}

/// One tenant's load loop.  Closed-loop waits each ticket inline;
/// open-loop keeps submitting on schedule and drains the outstanding
/// tickets after the window.  Latency and completion timestamps come
/// from the service's own stamps (`queue_wait_ms`/`e2e_ms`), so both
/// modes measure the same thing.
fn worker_loop(
    tenant: usize,
    opts: &BenchOpts,
    service: &StreamService,
    roster: &[crate::corpus::BenchConfig],
    epoch: Instant,
    live_done: &AtomicU64,
    live_shed: &AtomicU64,
) -> Vec<Event> {
    let rate = match opts.flood {
        Some((idx, factor)) if idx == tenant => opts.rate * factor.max(0.0),
        _ => opts.rate,
    };
    let name = tenant_name(tenant);
    let total = (rate * opts.secs).ceil() as usize;
    let interarrival = Duration::from_secs_f64(1.0 / rate.max(f64::MIN_POSITIVE));
    let mut events = Vec::with_capacity(total);
    let mut outstanding: Vec<(Ticket, f64)> = Vec::new();
    for k in 0..total {
        // Pace to the schedule; a slow previous wait means we're late
        // and submit immediately (no sleep), never early.
        let slot = epoch + interarrival.mul_f64(k as f64);
        let now = Instant::now();
        if slot > now {
            std::thread::sleep(slot - now);
        }
        let submitted_s = epoch.elapsed().as_secs_f64();
        let c = &roster[(tenant + k) % roster.len()];
        match service.submit(&name, Request::Corpus(c.clone())) {
            Err(Error::Admission { .. }) => {
                live_shed.fetch_add(1, Ordering::Relaxed);
                events.push(Event { tenant, t_s: submitted_s, kind: EventKind::Shed });
            }
            Err(_) => events.push(Event { tenant, t_s: submitted_s, kind: EventKind::Error }),
            Ok(ticket) if opts.open_loop => outstanding.push((ticket, submitted_s)),
            Ok(ticket) => {
                events.push(resolve(tenant, ticket, submitted_s, live_done));
            }
        }
    }
    for (ticket, submitted_s) in outstanding {
        events.push(resolve(tenant, ticket, submitted_s, live_done));
    }
    events
}

/// Wait one ticket and convert it to an event, timestamped at its
/// service-side completion (submit time + service e2e), which is exact
/// even when the open-loop drain waits tickets long after they landed.
fn resolve(tenant: usize, ticket: Ticket, submitted_s: f64, live_done: &AtomicU64) -> Event {
    match ticket.wait() {
        Ok(r) if r.ok() => {
            live_done.fetch_add(1, Ordering::Relaxed);
            let e2e_ms = r.e2e_ms;
            Event {
                tenant,
                t_s: submitted_s + e2e_ms.max(0.0) / 1e3,
                kind: EventKind::Done { e2e_ms, queue_ms: r.queue_wait_ms },
            }
        }
        Ok(_) | Err(_) => Event { tenant, t_s: submitted_s, kind: EventKind::Error },
    }
}

/// Bucket events into one-second ticks by completion time and compute
/// each tick's latency statistics — the reporter's merge step, pure so
/// the series is reproducible from any event log.
fn ticks_from_events(events: &[Event]) -> Vec<BenchTick> {
    let horizon = events.iter().map(|e| e.t_s).fold(0.0f64, f64::max);
    let n = (horizon.floor() as usize) + 1;
    let mut ticks: Vec<BenchTick> = (0..n as u64)
        .map(|t_s| BenchTick {
            t_s,
            lat_avg_ms: f64::NAN,
            lat_p50_ms: f64::NAN,
            lat_p99_ms: f64::NAN,
            queue_avg_ms: f64::NAN,
            ..Default::default()
        })
        .collect();
    let mut lat_by_tick: Vec<Vec<f64>> = vec![Vec::new(); n];
    let mut queue_by_tick: Vec<Vec<f64>> = vec![Vec::new(); n];
    for e in events {
        let idx = (e.t_s.max(0.0).floor() as usize).min(n - 1);
        match e.kind {
            EventKind::Done { e2e_ms, queue_ms } => {
                ticks[idx].completed += 1;
                lat_by_tick[idx].push(e2e_ms);
                queue_by_tick[idx].push(queue_ms);
            }
            EventKind::Shed => ticks[idx].rejected += 1,
            EventKind::Error => ticks[idx].errors += 1,
        }
    }
    for (i, t) in ticks.iter_mut().enumerate() {
        let (avg, p50, p99) = latency_stats(&lat_by_tick[i]);
        t.lat_avg_ms = avg;
        t.lat_p50_ms = p50;
        t.lat_p99_ms = p99;
        let (qavg, _, _) = latency_stats(&queue_by_tick[i]);
        t.queue_avg_ms = qavg;
    }
    ticks
}

/// Render the per-second series + totals as the CLI table.
pub fn bench_table(r: &BenchReport) -> Table {
    let num = |v: f64| if v.is_finite() { format!("{v:.2}") } else { "-".into() };
    let mut t = Table::new(
        format!(
            "Load bench — {} tenant(s) x {:.0} req/s for {:.0} s ({}), {} lanes, {} backend",
            r.tenants,
            r.rate,
            r.secs,
            if r.open_loop { "open-loop" } else { "closed-loop" },
            r.lanes,
            r.backend,
        ),
        &[
            "t (s)", "done", "shed", "err", "thr (req/s)", "avg (ms)", "p50 (ms)", "p99 (ms)",
            "queue (ms)", "mode", "lanes", "batches",
        ],
    );
    for tick in &r.ticks {
        t.row(&[
            tick.t_s.to_string(),
            tick.completed.to_string(),
            tick.rejected.to_string(),
            tick.errors.to_string(),
            num(tick.throughput_rps),
            num(tick.lat_avg_ms),
            num(tick.lat_p50_ms),
            num(tick.lat_p99_ms),
            num(tick.queue_avg_ms),
            tick.mode.clone(),
            tick.lanes.to_string(),
            tick.batches.to_string(),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn done(tenant: usize, t_s: f64, e2e_ms: f64) -> Event {
        Event { tenant, t_s, kind: EventKind::Done { e2e_ms, queue_ms: 1.0 } }
    }

    #[test]
    fn reporter_buckets_events_by_completion_second() {
        let events = vec![
            done(0, 0.2, 10.0),
            done(0, 0.9, 30.0),
            done(1, 1.5, 20.0),
            Event { tenant: 1, t_s: 0.5, kind: EventKind::Shed },
            Event { tenant: 0, t_s: 2.1, kind: EventKind::Error },
        ];
        let ticks = ticks_from_events(&events);
        assert_eq!(ticks.len(), 3);
        assert_eq!((ticks[0].completed, ticks[0].rejected, ticks[0].errors), (2, 1, 0));
        assert_eq!(ticks[0].lat_avg_ms, 20.0);
        assert_eq!(ticks[0].lat_p99_ms, 30.0, "nearest-rank p99 of two samples is the max");
        assert_eq!(ticks[1].completed, 1);
        assert!(ticks[2].lat_avg_ms.is_nan(), "a tick with no completions has unknown latency");
        assert_eq!(ticks[2].errors, 1);
    }

    #[test]
    fn reporter_handles_no_events() {
        let ticks = ticks_from_events(&[]);
        assert_eq!(ticks.len(), 1);
        assert_eq!(ticks[0].completed, 0);
    }
}
