//! `repro verify [--corpus] [--spec FILE]` — the offline hazard proof
//! over the corpus lowerings, or over one user spec (DESIGN.md
//! §Verification, §Spec).
//!
//! Every representative Table-1 app lowers at a granularity ladder and
//! runs through [`crate::plan::verify`]: structural sanity, byte-
//! interval race freedom under the backend dependency contract, exact
//! output tiling, and arena must-zero coverage — statically, nothing
//! executes.  `--corpus` sweeps [`mirror_check_granularities`] (56 apps
//! × 4 granularities = 224 plans, the same population the Python
//! mirror's `native_output_path_check` proves, duplicates kept so the
//! two sides count identically); without it, only each app's default
//! granularity (56 plans, the per-commit smoke).  `--json` emits the
//! structured verdicts the CI cross-check diffs against
//! `tuner_mirror.py --native-check --json`.
//!
//! `StreamPlan::validate` runs alongside the verifier on every row:
//! signature conformance + hazard freedom compose into the full static
//! proof (the verifier trusts Kex regions as declared).  A row fails on
//! either, and the CLI exits non-zero if any row fails.

use crate::corpus::BenchConfig;
use crate::metrics::Table;
use crate::plan::{
    default_corpus_granularity, lower_corpus_streamed_at, mirror_check_granularities, verify_plan,
    Granularity, StreamPlan, VerifyReport, CORPUS_BURNER,
};
use crate::spec::{SpecCompiler, WorkloadSpec};
use crate::util::json::escape;

use super::sweep::representative_configs;

/// One (app, granularity) verification verdict.
#[derive(Debug, Clone)]
pub struct VerifyRow {
    pub suite: &'static str,
    pub app: String,
    pub config: String,
    pub category: &'static str,
    /// Requested granularity (pre-clamp — the mirror keys on it too).
    pub gran: usize,
    /// `StreamPlan::validate` verdict (signature conformance).
    pub valid: bool,
    /// Validation error text, if any.
    pub valid_error: Option<String>,
    /// The hazard verifier's structured report.
    pub report: VerifyReport,
    /// The row's verdict: validated and hazard-free (tiling included).
    pub ok: bool,
}

fn verify_one(c: &BenchConfig, gran: Granularity) -> VerifyRow {
    let plan = lower_corpus_streamed_at(c, CORPUS_BURNER, gran);
    let valid_error = plan.validate().err().map(|e| e.to_string());
    let report = verify_plan(&plan);
    let ok = valid_error.is_none() && report.is_clean();
    VerifyRow {
        suite: c.suite.label(),
        app: c.app.to_string(),
        config: c.config.clone(),
        category: c.category().label(),
        gran: gran.get(),
        valid: valid_error.is_none(),
        valid_error,
        report,
        ok,
    }
}

/// Verify the corpus: all 224 (app × granularity) lowerings with
/// `corpus`, each app's default granularity otherwise.  Returns the
/// rendered table, the rows, and the failed-row count (the CLI's exit
/// status).
pub fn verify_corpus(corpus: bool) -> (Table, Vec<VerifyRow>, usize) {
    let configs = representative_configs(false);
    let mut rows = Vec::new();
    for c in &configs {
        let grans: Vec<Granularity> = if corpus {
            mirror_check_granularities(c.category()).to_vec()
        } else {
            vec![default_corpus_granularity(c.category())]
        };
        for g in grans {
            rows.push(verify_one(c, g));
        }
    }
    let failed = rows.iter().filter(|r| !r.ok).count();
    let t = render_table(&rows, failed);
    (t, rows, failed)
}

/// The rows as one JSON document (`repro verify --json`) — the Rust
/// half of the CI cross-check (`tools/verify_crosscheck.py` diffs the
/// (app, config, gran, ok) verdicts against the Python mirror's).
pub fn verify_rows_json(rows: &[VerifyRow]) -> String {
    let failed = rows.iter().filter(|r| !r.ok).count();
    let mut s = String::from("{\"schema\":\"hetstream-verify-v1\",\"rows\":[");
    for (i, r) in rows.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!(
            "{{\"suite\":\"{}\",\"app\":\"{}\",\"config\":\"{}\",\"category\":\"{}\",\
             \"gran\":{},\"ok\":{},\"valid\":{},\"valid_error\":{},\"report\":{}}}",
            escape(r.suite),
            escape(&r.app),
            escape(&r.config),
            escape(r.category),
            r.gran,
            r.ok,
            r.valid,
            r.valid_error
                .as_ref()
                .map_or("null".to_string(), |e| format!("\"{}\"", escape(e))),
            r.report.to_json()
        ));
    }
    s.push_str(&format!("],\"total\":{},\"failed\":{failed}}}", rows.len()));
    s
}

/// Shared table rendering for corpus and spec verification rows.
fn render_table(rows: &[VerifyRow], failed: usize) -> Table {
    let mut t = Table::new(
        format!(
            "Static hazard verification — {} (app, granularity) lowerings, {} failed",
            rows.len(),
            failed
        ),
        &["suite", "app", "config", "category", "gran", "ops", "accesses", "conflicts", "verdict"],
    );
    for r in rows {
        let verdict = if r.ok {
            "clean".to_string()
        } else if !r.valid {
            "INVALID".to_string()
        } else {
            format!("{} HAZARD(S)", r.report.hazards.len())
        };
        t.row(&[
            r.suite.to_string(),
            r.app.clone(),
            r.config.clone(),
            r.category.to_string(),
            r.gran.to_string(),
            r.report.ops.to_string(),
            r.report.accesses.to_string(),
            r.report.conflicts.to_string(),
            verdict,
        ]);
    }
    t
}

/// One verification row over an already-lowered spec plan.
fn spec_row(spec: &WorkloadSpec, plan: &StreamPlan, config: &str, gran: usize) -> VerifyRow {
    let valid_error = plan.validate().err().map(|e| e.to_string());
    let report = verify_plan(plan);
    let ok = valid_error.is_none() && report.is_clean();
    VerifyRow {
        suite: "spec",
        app: spec.name.clone(),
        config: config.to_string(),
        category: spec.category.label(),
        gran,
        valid: valid_error.is_none(),
        valid_error,
        report,
        ok,
    }
}

/// Verify one user spec (`repro verify --spec FILE`): the bulk
/// reference plus a streamed granularity ladder around the spec's
/// default, every row demanded hazard-free *including* the
/// strictness-only tiling findings — stricter than `run-spec`'s
/// fatal-only execution gate.  Returns the rendered table, the rows,
/// and the failed-row count (the CLI's exit status).
pub fn verify_spec(spec: &WorkloadSpec) -> (Table, Vec<VerifyRow>, usize) {
    let compiler = SpecCompiler::new(spec);
    let mut rows = vec![spec_row(spec, &compiler.bulk(), "bulk", 1)];
    // Requested ladder; the unified clamp dedupes aliased points so no
    // plan is verified twice under different labels.
    let mut seen = std::collections::HashSet::new();
    for g in [1, spec.granularity, spec.granularity.saturating_mul(2)] {
        let eff = compiler.effective_granularity(Granularity::new(g)).get();
        if !seen.insert(eff) {
            continue;
        }
        rows.push(spec_row(spec, &compiler.streamed_at(Granularity::new(eff)), "streamed", eff));
    }
    let failed = rows.iter().filter(|r| !r.ok).count();
    let t = render_table(&rows, failed);
    (t, rows, failed)
}

#[cfg(test)]
mod spec_tests {
    use super::*;

    #[test]
    fn a_valid_spec_verifies_clean_at_every_ladder_point() {
        let spec = WorkloadSpec::from_json(
            r#"{
                "schema": "hetstream-spec-v1",
                "name": "vs-demo",
                "category": "independent",
                "mode": "windows",
                "granularity": 4,
                "output_bytes": 4096,
                "buffers": [
                    {"name": "a", "bytes": 4096, "init": {"kind": "f32_rand", "seed": 3}}
                ],
                "stages": [{"kernel": "burner_8", "inputs": ["a"]}]
            }"#,
        )
        .expect("demo spec parses");
        spec.validate().unwrap();
        let (_, rows, failed) = verify_spec(&spec);
        assert_eq!(failed, 0, "hazards: {:?}", rows.iter().filter(|r| !r.ok).count());
        assert!(rows.len() >= 3, "bulk + a deduped streamed ladder");
        assert!(rows.iter().all(|r| r.app == "vs-demo" && r.suite == "spec"));
        // The JSON dump covers spec rows the same as corpus rows.
        let v = crate::util::json::Json::parse(&verify_rows_json(&rows)).expect("valid JSON");
        assert_eq!(v.get("failed").and_then(|n| n.as_usize()), Some(0));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_granularity_corpus_verifies_clean() {
        // The quick (non --corpus) population: every representative app
        // at its default granularity must be valid and hazard-free.
        let (_, rows, failed) = verify_corpus(false);
        assert_eq!(rows.len(), 56);
        assert_eq!(
            failed,
            0,
            "hazardous default lowerings: {:?}",
            rows.iter().filter(|r| !r.ok).map(|r| (r.app.as_str(), r.gran)).collect::<Vec<_>>()
        );
        assert!(
            rows.iter().all(|r| r.report.conflicts > 0 || r.report.ops <= 1),
            "a corpus verification that discharges no conflict pairs is vacuous"
        );
    }

    #[test]
    fn verify_rows_json_parses_and_counts() {
        let (_, rows, _) = verify_corpus(false);
        let v = crate::util::json::Json::parse(&verify_rows_json(&rows)).expect("valid JSON");
        assert_eq!(v.get("total").and_then(|n| n.as_usize()), Some(rows.len()));
        assert_eq!(v.get("failed").and_then(|n| n.as_usize()), Some(0));
        let arr = v.get("rows").and_then(|r| r.as_arr()).expect("rows array");
        assert_eq!(arr.len(), rows.len());
        assert_eq!(arr[0].get("ok").and_then(|b| b.as_bool()), Some(true));
        assert!(arr[0].get("report").and_then(|r| r.get("clean")).is_some());
    }
}
