//! E2 / Fig. 2: R as a function of the input dataset — `lbm`
//! (short vs long) and `FDTD3d` (timestep count).

use crate::corpus::configs_for;
use crate::device::DeviceProfile;
use crate::hstreams::Context;
use crate::metrics::Table;

/// Measure the Fig. 2 apps.  `ctx = None` uses the analytic model.
pub fn fig2(ctx: Option<&Context>, profile: &DeviceProfile, runs: usize) -> Table {
    let mut t = Table::new(
        "Fig. 2 — R changes over datasets (lbm, FDTD3d)",
        &["app", "config", "R_H2D", "R_KEX", "R_D2H"],
    );
    for app in ["lbm", "FDTD3d"] {
        for cfg in configs_for(app) {
            let st = match ctx {
                Some(c) => {
                    crate::analysis::measure_stages(c, &super::fig1::offload_spec(&cfg), runs)
                }
                None => super::analytic_stage_times(&cfg, profile),
            };
            t.row(&[
                app.to_string(),
                cfg.config.clone(),
                format!("{:.3}", st.r_h2d()),
                format!("{:.3}", st.r_kex()),
                format!("{:.3}", st.r_d2h()),
            ]);
        }
    }
    t
}
