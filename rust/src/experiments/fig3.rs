//! E3 / Fig. 3: R across the Reduction v1/v2 code variants — different
//! codes generate different transfer requirements.

use crate::corpus::configs_for;
use crate::device::DeviceProfile;
use crate::hstreams::Context;
use crate::metrics::Table;

/// Measure both Reduction variants.  `ctx = None` uses the analytic
/// model; otherwise stage-by-stage through the engines.
pub fn fig3(ctx: Option<&Context>, profile: &DeviceProfile, runs: usize) -> Table {
    let mut t = Table::new(
        "Fig. 3 — R changes over code variants (Reduction v1 vs v2)",
        &["variant", "config", "R_H2D", "R_D2H", "D2H bytes"],
    );
    for app in ["Reduction", "Reduction-2"] {
        for cfg in configs_for(app) {
            let st = match ctx {
                Some(c) => {
                    crate::analysis::measure_stages(c, &super::fig1::offload_spec(&cfg), runs)
                }
                None => super::analytic_stage_times(&cfg, profile),
            };
            t.row(&[
                app.to_string(),
                cfg.config.clone(),
                format!("{:.3}", st.r_h2d()),
                format!("{:.4}", st.r_d2h()),
                cfg.d2h_bytes.to_string(),
            ]);
        }
    }
    t
}
