//! The full-corpus streaming sweep (`repro sweep --corpus`) and the
//! joint (streams × granularity) tuner (`repro tune --corpus`): every
//! Table-1 application lowers to its [`crate::plan::StreamPlan`] and
//! runs through the one executor across a stream-count ladder — or the
//! whole tuning grid — under the virtual clock: sleep-free,
//! deterministic, per-commit cheap.
//!
//! Validation is executor-level: the outputs of every sweep ladder
//! point must equal the 1-stream run bit-for-bit, and every tuning
//! grid point must equal the *bulk* lowering bit-for-bit (same kernels
//! over the same bytes, any placement, any granularity).  With
//! `--native` the sweep also pushes every app's plan through the
//! [`crate::plan::NativeBackend`] and demands the same bytes — the
//! per-commit backend-equivalence check.  A structural
//! `plan.validate()` failure or a mis-validated run marks the row
//! failed; the CLI exits non-zero if any row fails, which is what the
//! CI smoke jobs check.

use crate::analysis::{
    analytic_corpus_seed, argmin, autotune_plan, autotune_plan_pruned, corpus_features,
    gran_ladder, normalize_ladder, predict_streams_for_plan, KnnTuner, PlanTuneResult,
};
use crate::corpus::{all_configs, BenchConfig};
use crate::hstreams::Context;
use crate::metrics::Table;
use crate::plan::{
    default_corpus_granularity, effective_corpus_granularity, lower_corpus_bulk,
    lower_corpus_streamed, lower_corpus_streamed_at, outputs_match, Backend, Granularity,
    NativeBackend, RunConfig, SimBackend, CORPUS_BURNER,
};
use crate::util::improvement_pct;
use crate::Result;

/// One corpus app's ladder measurement.
#[derive(Debug, Clone)]
pub struct SweepRow {
    pub suite: &'static str,
    pub app: &'static str,
    pub config: String,
    pub category: &'static str,
    pub tasks: usize,
    /// (streams, modeled ms) per ladder point; index 0 is the 1-stream
    /// reference.
    pub ladder: Vec<(usize, f64)>,
    pub best_streams: usize,
    /// Paper metric vs the 1-stream pipeline: (t1 / t_best − 1) · 100.
    pub improvement_pct: f64,
    /// Analytic §6 stream-count suggestion from the plan features.
    pub predicted_streams: usize,
    pub validated: bool,
    pub error: Option<String>,
}

/// The corpus rows a sweep/tune covers: every configuration, or the
/// first (representative) one per (app, suite) — one policy for both
/// tables so they always cover the same population.
pub(crate) fn representative_configs(all_cfgs: bool) -> Vec<BenchConfig> {
    let mut configs = all_configs();
    if !all_cfgs {
        let mut seen = std::collections::HashSet::new();
        configs.retain(|c| seen.insert((c.app, c.suite)));
    }
    configs
}

fn sweep_one(
    ctx: &Context,
    c: &BenchConfig,
    ladder: &[usize],
    native: Option<&NativeBackend>,
) -> SweepRow {
    let mut row = SweepRow {
        suite: c.suite.label(),
        app: c.app,
        config: c.config.clone(),
        category: c.category().label(),
        tasks: 0,
        ladder: Vec::new(),
        best_streams: 1,
        improvement_pct: 0.0,
        predicted_streams: 0,
        validated: false,
        error: None,
    };
    let plan = lower_corpus_streamed(c, CORPUS_BURNER);
    if let Err(e) = plan.validate() {
        row.error = Some(e.to_string());
        return row;
    }
    row.tasks = plan.tasks();
    row.predicted_streams = predict_streams_for_plan(&plan, ctx.profile());
    let exec = SimBackend::new(ctx);

    let reference = match exec.run(&plan, RunConfig::streams(1)) {
        Ok(r) => r,
        Err(e) => {
            row.error = Some(e.to_string());
            return row;
        }
    };
    let t1 = reference.wall.as_secs_f64() * 1e3;
    row.ladder.push((1, t1));
    row.validated = true;

    // --native: the same plan through the host thread-pool backend
    // must assemble the sim reference's bytes exactly — the per-commit
    // form of the backend-equivalence acceptance over all 56 apps.
    if let Some(native) = native {
        match native.run(&plan, RunConfig::streams(4)) {
            Ok(r) if outputs_match(&reference, &r) => {}
            Ok(_) => {
                row.validated = false;
                row.error.get_or_insert_with(|| "native backend outputs diverge".into());
            }
            Err(e) => {
                row.validated = false;
                row.error.get_or_insert_with(|| format!("native backend: {e}"));
            }
        }
    }

    for &n in ladder.iter().filter(|&&n| n > 1) {
        match exec.run(&plan, RunConfig::streams(n)) {
            Ok(r) if outputs_match(&reference, &r) => {
                row.ladder.push((n, r.wall.as_secs_f64() * 1e3));
            }
            // Mis-validated points stay out of the ladder — a "best"
            // time from a run with wrong outputs is not a result — and
            // the first failure cause is the one reported.
            Ok(_) => {
                row.validated = false;
                row.error.get_or_insert_with(|| format!("outputs diverge at {n} streams"));
            }
            Err(e) => {
                row.validated = false;
                row.error.get_or_insert_with(|| e.to_string());
            }
        }
    }

    // Shared NaN-safe argmin (total order; first-seen tie-break, so
    // exact virtual-clock ties report the smallest stream count, like
    // the tuner).
    let (bn, bt) = argmin(row.ladder.iter().copied()).unwrap_or((1, t1));
    row.best_streams = bn;
    row.improvement_pct = improvement_pct(t1, bt);
    row
}

/// Sweep the corpus: one representative (first) configuration per app,
/// or every configuration with `all_cfgs`.  Returns the rendered table,
/// the rows, and the number of failed rows.
pub fn sweep_corpus(
    ctx: &Context,
    ladder: &[usize],
    all_cfgs: bool,
) -> Result<(Table, Vec<SweepRow>, usize)> {
    sweep_corpus_with(ctx, ladder, all_cfgs, false)
}

/// [`sweep_corpus`], optionally cross-checking every app through the
/// [`NativeBackend`] (`repro sweep --corpus --native`): both `Backend`
/// implementations must assemble bitwise-identical outputs for every
/// corpus plan, and a divergence fails the row like any
/// mis-validation.
pub fn sweep_corpus_with(
    ctx: &Context,
    ladder: &[usize],
    all_cfgs: bool,
    native: bool,
) -> Result<(Table, Vec<SweepRow>, usize)> {
    let configs = representative_configs(all_cfgs);
    let native = native.then(NativeBackend::new);
    let rows: Vec<SweepRow> =
        configs.iter().map(|c| sweep_one(ctx, c, ladder, native.as_ref())).collect();

    let ladder_label = ladder.iter().map(|n| n.to_string()).collect::<Vec<_>>().join("/");
    let mut t = Table::new(
        format!("Corpus sweep — StreamPlan executor, {ladder_label} streams"),
        &[
            "suite", "app", "config", "category", "tasks", "1-stream (ms)", "best", "improvement",
            "predicted", "valid",
        ],
    );
    for r in &rows {
        let t1 = r.ladder.first().map(|&(_, ms)| ms).unwrap_or(f64::NAN);
        let best = r
            .ladder
            .iter()
            .find(|&&(n, _)| n == r.best_streams)
            .map(|&(n, ms)| format!("{ms:.2} ms @{n}"))
            .unwrap_or_else(|| "-".into());
        t.row(&[
            r.suite.to_string(),
            r.app.to_string(),
            r.config.clone(),
            r.category.to_string(),
            r.tasks.to_string(),
            format!("{t1:.2}"),
            best,
            if r.improvement_pct.is_finite() {
                format!("{:+.1}%", r.improvement_pct)
            } else {
                "-".into()
            },
            r.predicted_streams.to_string(),
            match &r.error {
                Some(e) => format!("FAIL: {e}"),
                None => r.validated.to_string(),
            },
        ]);
    }
    let failures = rows.iter().filter(|r| r.error.is_some() || !r.validated).count();
    Ok((t, rows, failures))
}

/// How `tune_corpus_with` searches each app's candidate grid.
#[derive(Clone, Copy)]
pub enum TuneStrategy<'a> {
    /// Measure the full candidate grid (`analysis::autotune_plan`).
    Exhaustive,
    /// Hill-climb outward from a seed (`analysis::autotune_plan_pruned`):
    /// the k-NN prediction when a model is given and covers the app's
    /// category, the analytic seed otherwise.
    Pruned { model: Option<&'a KnnTuner> },
}

/// One corpus app's joint (streams × granularity) tuning outcome.
#[derive(Debug, Clone)]
pub struct TuneRow {
    pub suite: &'static str,
    pub app: &'static str,
    pub config: String,
    pub category: &'static str,
    /// Seed (streams, granularity) the search started from — analytic
    /// plan features, or the k-NN prediction when `seed_learned`.
    pub seed: (usize, usize),
    /// Whether the seed came from the learned model (vs analytic).
    pub seed_learned: bool,
    pub best_streams: usize,
    pub best_gran: usize,
    pub best_ms: f64,
    /// Best time over the stream ladder at the *fixed* pre-tuner
    /// granularity (the PR-2 sweep baseline).  NaN when a pruned walk
    /// never visited that column.
    pub fixed_ms: f64,
    /// Bulk (non-streamed) reference, ms.
    pub bulk_ms: f64,
    /// (t_fixed / t_best − 1) · 100: what the granularity knob buys on
    /// top of stream-count tuning alone.  NaN when `fixed_ms` is
    /// unknown or the row failed — never a number fabricated from NaN
    /// operands.
    pub improvement_pct: f64,
    /// Measured surface: (streams, granularity, ms) — the full grid for
    /// `Exhaustive`, only visited points for `Pruned`.
    pub surface: Vec<(usize, usize, f64)>,
    /// Size of the full candidate grid (streams × granularity) the
    /// search could have measured; `surface.len()` over this is the
    /// measured fraction.
    pub grid: usize,
    pub validated: bool,
    pub error: Option<String>,
}

fn tune_one(
    ctx: &Context,
    c: &BenchConfig,
    streams: &[usize],
    grans: &[usize],
    runs: usize,
    strategy: TuneStrategy<'_>,
) -> TuneRow {
    // Normalize the stream ladder with the searches' own rule so
    // `grid` counts the points a search could actually measure —
    // `--ladder 0,1,2` must not inflate the denominator of the
    // measured fraction.
    let streams = normalize_ladder(streams);
    let mut row = TuneRow {
        suite: c.suite.label(),
        app: c.app,
        config: c.config.clone(),
        category: c.category().label(),
        seed: (0, 0),
        seed_learned: false,
        best_streams: 1,
        best_gran: 1,
        best_ms: f64::NAN,
        fixed_ms: f64::NAN,
        bulk_ms: f64::NAN,
        improvement_pct: f64::NAN,
        surface: Vec::new(),
        grid: 0,
        validated: false,
        error: None,
    };
    let bulk = lower_corpus_bulk(c, CORPUS_BURNER);

    // Analytic seed in the category's knob units, clamped to what the
    // lowering will actually use — the same rule the service layer's
    // analytic policy applies (`analysis::analytic_corpus_seed`).
    let (seed_streams, analytic_gran) = analytic_corpus_seed(c, ctx.profile());

    // The learned seed, when a model is given and has same-category
    // training rows (its granularity labels are already effective knob
    // units — `tune_corpus` produced them).  Analytic otherwise.
    row.seed = (seed_streams, analytic_gran);
    if let TuneStrategy::Pruned { model: Some(model) } = strategy {
        if let Some((s, g)) = model.predict(&corpus_features(c, ctx.profile())) {
            row.seed = (s, effective_corpus_granularity(c, Granularity::new(g)).get());
            row.seed_learned = true;
        }
    }

    // Candidate grid: the caller's ladder grown around the *analytic*
    // seed, plus the fixed pre-tuner granularity (so the improvement
    // column compares like with like) — everything mapped to effective
    // knob values and deduped, or aliased points would be measured
    // twice under different labels (and sync/iterative apps, which
    // ignore the knob, would re-measure one plan per candidate).  The
    // grid is strategy-independent: a pruned walk prunes *visits*, not
    // candidates, so its measured fraction is comparable.
    let fixed_gran =
        effective_corpus_granularity(c, default_corpus_granularity(c.category())).get();
    let mut grans: Vec<usize> = grans
        .iter()
        .copied()
        .chain(gran_ladder(analytic_gran))
        .chain([fixed_gran])
        .map(|g| effective_corpus_granularity(c, Granularity::new(g)).get())
        .collect();
    grans.sort_unstable();
    grans.dedup();
    row.grid = streams.len() * grans.len();

    let lower = |g| lower_corpus_streamed_at(c, CORPUS_BURNER, g);
    let result: Result<PlanTuneResult> = match strategy {
        TuneStrategy::Exhaustive => autotune_plan(ctx, &bulk, &lower, &streams, &grans, runs),
        TuneStrategy::Pruned { .. } => {
            autotune_plan_pruned(ctx, &bulk, &lower, &streams, &grans, row.seed, runs)
        }
    };
    match result {
        Ok(r) => {
            row.best_streams = r.best_streams;
            row.best_gran = r.best_gran;
            row.best_ms = r.best_ms;
            row.bulk_ms = r.bulk_ms;
            row.fixed_ms = argmin(
                r.surface
                    .iter()
                    .filter(|&&(_, g, _)| g == fixed_gran)
                    .map(|&(n, _, ms)| (n, ms)),
            )
            .map(|(_, ms)| ms)
            .unwrap_or(f64::NAN);
            // Guarded (shared `util::improvement_pct` rule): a NaN
            // operand — failed/unvisited fixed column, degenerate zero
            // best — surfaces as "unknown", never as a NaN-propagated
            // percentage the table prints as a number.
            row.improvement_pct = improvement_pct(row.fixed_ms, row.best_ms);
            row.surface = r.surface;
            row.validated = true;
        }
        Err(e) => row.error = Some(e.to_string()),
    }
    row
}

/// Tune the corpus exhaustively — see [`tune_corpus_with`].
pub fn tune_corpus(
    ctx: &Context,
    streams: &[usize],
    grans: &[usize],
    all_cfgs: bool,
    runs: usize,
) -> Result<(Table, Vec<TuneRow>, usize)> {
    tune_corpus_with(ctx, streams, grans, all_cfgs, runs, TuneStrategy::Exhaustive)
}

/// Tune the corpus: one representative (first) configuration per app,
/// or every configuration with `all_cfgs`.  Every measured point is
/// validated bitwise against the bulk lowering.  Returns the rendered
/// per-app tuning table, the rows (with measured surfaces), and the
/// number of failed rows.
///
/// Errored rows render `-` in every result column: their struct
/// defaults (`best = (1, 1)`, NaN times) are placeholders, and printing
/// them as numbers made a failed row indistinguishable from a genuine
/// optimum at one stream × granularity 1 (the JSON path already nulls
/// non-finite metrics).
pub fn tune_corpus_with(
    ctx: &Context,
    streams: &[usize],
    grans: &[usize],
    all_cfgs: bool,
    runs: usize,
    strategy: TuneStrategy<'_>,
) -> Result<(Table, Vec<TuneRow>, usize)> {
    let configs = representative_configs(all_cfgs);
    let rows = tune_configs(ctx, &configs, streams, grans, runs, strategy);

    let mut t = Table::new(
        format!(
            "Corpus joint tuner — streams {:?} × granularity {:?}, validated vs bulk",
            streams, grans
        ),
        &[
            "suite", "app", "config", "category", "seed (s,g)", "best (s,g)", "best (ms)",
            "fixed-g (ms)", "gain", "measured", "valid",
        ],
    );
    let num = |v: f64| if v.is_finite() { format!("{v:.2}") } else { "-".into() };
    for r in &rows {
        let failed = r.error.is_some() || !r.validated;
        t.row(&[
            r.suite.to_string(),
            r.app.to_string(),
            r.config.clone(),
            r.category.to_string(),
            format!("({}, {}){}", r.seed.0, r.seed.1, if r.seed_learned { "*" } else { "" }),
            if failed { "-".into() } else { format!("({}, {})", r.best_streams, r.best_gran) },
            if failed { "-".into() } else { num(r.best_ms) },
            if failed { "-".into() } else { num(r.fixed_ms) },
            if !failed && r.improvement_pct.is_finite() {
                format!("{:+.1}%", r.improvement_pct)
            } else {
                "-".into()
            },
            format!("{}/{}", r.surface.len(), r.grid),
            match &r.error {
                Some(e) => format!("FAIL: {e}"),
                None => r.validated.to_string(),
            },
        ]);
    }
    let failures = rows.iter().filter(|r| r.error.is_some() || !r.validated).count();
    Ok((t, rows, failures))
}

/// Tune an explicit set of descriptors (the CV harness holds apps out
/// one at a time and needs per-config control; `tune_corpus_with` is
/// the whole-population wrapper).
pub(crate) fn tune_configs(
    ctx: &Context,
    configs: &[BenchConfig],
    streams: &[usize],
    grans: &[usize],
    runs: usize,
    strategy: TuneStrategy<'_>,
) -> Vec<TuneRow> {
    configs.iter().map(|c| tune_one(ctx, c, streams, grans, runs, strategy)).collect()
}

/// JSON rendering of the tuning rows (full surfaces included): the
/// feature/label set the ROADMAP's learned-tuner line consumes.
pub fn tune_rows_json(rows: &[TuneRow]) -> String {
    use crate::util::json::escape;
    // JSON has no NaN: failed rows carry null metrics.
    let num = |v: f64| if v.is_finite() { format!("{v:.6}") } else { "null".into() };
    let mut s = String::from("{\"rows\":[");
    for (i, r) in rows.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!(
            "{{\"suite\":\"{}\",\"app\":\"{}\",\"config\":\"{}\",\"category\":\"{}\",\
             \"seed\":[{},{}],\"seed_learned\":{},\
             \"best\":{{\"streams\":{},\"gran\":{},\"ms\":{}}},\
             \"fixed_ms\":{},\"bulk_ms\":{},\"improvement_pct\":{},\
             \"visited\":{},\"grid\":{},\
             \"validated\":{},\"error\":{},\"surface\":[",
            escape(r.suite),
            escape(r.app),
            escape(&r.config),
            escape(r.category),
            r.seed.0,
            r.seed.1,
            r.seed_learned,
            r.best_streams,
            r.best_gran,
            num(r.best_ms),
            num(r.fixed_ms),
            num(r.bulk_ms),
            num(r.improvement_pct),
            r.surface.len(),
            r.grid,
            r.validated,
            match &r.error {
                Some(e) => format!("\"{}\"", escape(e)),
                None => "null".into(),
            },
        ));
        for (j, &(n, g, ms)) in r.surface.iter().enumerate() {
            if j > 0 {
                s.push(',');
            }
            s.push_str(&format!("[{n},{g},{}]", num(ms)));
        }
        s.push_str("]}");
    }
    s.push_str("]}");
    s
}
