//! The full-corpus streaming sweep (`repro sweep --corpus`): every
//! Table-1 application lowers to its [`crate::plan::StreamPlan`] and
//! runs through the one executor across a stream-count ladder, under
//! the virtual clock — sleep-free, deterministic, per-commit cheap.
//!
//! Validation is executor-level: the outputs of every ladder point must
//! equal the 1-stream run bit-for-bit (same kernels over the same
//! bytes, any placement).  A structural `plan.validate()` failure or a
//! mis-validated run marks the row failed; the CLI exits non-zero if
//! any row fails, which is what the CI smoke job checks.

use crate::analysis::predict_streams_for_plan;
use crate::corpus::{all_configs, BenchConfig};
use crate::hstreams::Context;
use crate::metrics::Table;
use crate::plan::{lower_corpus_streamed, outputs_match, Executor, CORPUS_BURNER};
use crate::Result;

/// One corpus app's ladder measurement.
#[derive(Debug, Clone)]
pub struct SweepRow {
    pub suite: &'static str,
    pub app: &'static str,
    pub config: String,
    pub category: &'static str,
    pub tasks: usize,
    /// (streams, modeled ms) per ladder point; index 0 is the 1-stream
    /// reference.
    pub ladder: Vec<(usize, f64)>,
    pub best_streams: usize,
    /// Paper metric vs the 1-stream pipeline: (t1 / t_best − 1) · 100.
    pub improvement_pct: f64,
    /// Analytic §6 stream-count suggestion from the plan features.
    pub predicted_streams: usize,
    pub validated: bool,
    pub error: Option<String>,
}

fn sweep_one(ctx: &Context, c: &BenchConfig, ladder: &[usize]) -> SweepRow {
    let mut row = SweepRow {
        suite: c.suite.label(),
        app: c.app,
        config: c.config.clone(),
        category: c.category().label(),
        tasks: 0,
        ladder: Vec::new(),
        best_streams: 1,
        improvement_pct: 0.0,
        predicted_streams: 0,
        validated: false,
        error: None,
    };
    let plan = lower_corpus_streamed(c, CORPUS_BURNER);
    if let Err(e) = plan.validate() {
        row.error = Some(e.to_string());
        return row;
    }
    row.tasks = plan.tasks();
    row.predicted_streams = predict_streams_for_plan(&plan, ctx.profile());
    let exec = Executor::new(ctx);

    let reference = match exec.run(&plan, 1) {
        Ok(r) => r,
        Err(e) => {
            row.error = Some(e.to_string());
            return row;
        }
    };
    let t1 = reference.wall.as_secs_f64() * 1e3;
    row.ladder.push((1, t1));
    row.validated = true;

    for &n in ladder.iter().filter(|&&n| n > 1) {
        match exec.run(&plan, n) {
            Ok(r) if outputs_match(&reference, &r) => {
                row.ladder.push((n, r.wall.as_secs_f64() * 1e3));
            }
            // Mis-validated points stay out of the ladder — a "best"
            // time from a run with wrong outputs is not a result — and
            // the first failure cause is the one reported.
            Ok(_) => {
                row.validated = false;
                row.error.get_or_insert_with(|| format!("outputs diverge at {n} streams"));
            }
            Err(e) => {
                row.validated = false;
                row.error.get_or_insert_with(|| e.to_string());
            }
        }
    }

    let (bn, bt) = row
        .ladder
        .iter()
        .copied()
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap_or((1, t1));
    row.best_streams = bn;
    row.improvement_pct = (t1 / bt - 1.0) * 100.0;
    row
}

/// Sweep the corpus: one representative (first) configuration per app,
/// or every configuration with `all_cfgs`.  Returns the rendered table,
/// the rows, and the number of failed rows.
pub fn sweep_corpus(
    ctx: &Context,
    ladder: &[usize],
    all_cfgs: bool,
) -> Result<(Table, Vec<SweepRow>, usize)> {
    let mut configs = all_configs();
    if !all_cfgs {
        let mut seen = std::collections::HashSet::new();
        configs.retain(|c| seen.insert((c.app, c.suite)));
    }

    let rows: Vec<SweepRow> = configs.iter().map(|c| sweep_one(ctx, c, ladder)).collect();

    let ladder_label = ladder.iter().map(|n| n.to_string()).collect::<Vec<_>>().join("/");
    let mut t = Table::new(
        format!("Corpus sweep — StreamPlan executor, {ladder_label} streams"),
        &[
            "suite", "app", "config", "category", "tasks", "1-stream (ms)", "best", "improvement",
            "predicted", "valid",
        ],
    );
    for r in &rows {
        let t1 = r.ladder.first().map(|&(_, ms)| ms).unwrap_or(f64::NAN);
        let best = r
            .ladder
            .iter()
            .find(|&&(n, _)| n == r.best_streams)
            .map(|&(n, ms)| format!("{ms:.2} ms @{n}"))
            .unwrap_or_else(|| "-".into());
        t.row(&[
            r.suite.to_string(),
            r.app.to_string(),
            r.config.clone(),
            r.category.to_string(),
            r.tasks.to_string(),
            format!("{t1:.2}"),
            best,
            format!("{:+.1}%", r.improvement_pct),
            r.predicted_streams.to_string(),
            match &r.error {
                Some(e) => format!("FAIL: {e}"),
                None => r.validated.to_string(),
            },
        ]);
    }
    let failures = rows.iter().filter(|r| r.error.is_some() || !r.validated).count();
    Ok((t, rows, failures))
}
