//! Experiment drivers: one function per paper table/figure, shared by
//! the `repro` CLI and the criterion benches so every number in
//! EXPERIMENTS.md is regenerable from two entry points.

pub mod bench;
pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig9;
pub mod lavamd;
pub mod learn;
pub mod run_spec;
pub mod serve;
pub mod sweep;
pub mod table2;
pub mod verify;

pub use bench::{bench_table, run_bench, BenchOpts};
pub use fig1::{fig1_analytic, fig1_engine, offload_spec, Fig1Row};
pub use fig2::fig2;
pub use fig3::fig3;
pub use fig4::fig4;
pub use fig9::{fig9, measure_one, rgain, Fig9Row};
pub use lavamd::lavamd_negative;
pub use learn::{dataset_from_tune_rows, dataset_table, learn_cv, learn_dataset, CvStats};
pub use run_spec::{
    compile_spec, run_spec, run_spec_json, tune_spec, RunSpecOpts, RunSpecOutcome, SpecTune,
};
pub use serve::{demo_roster, serve_demo, ServeSummary};
pub use sweep::{
    sweep_corpus, sweep_corpus_with, tune_corpus, tune_corpus_with, tune_rows_json, SweepRow,
    TuneRow, TuneStrategy,
};
pub use table2::table2;
pub use verify::{verify_corpus, verify_rows_json, verify_spec, VerifyRow};

use crate::corpus::BenchConfig;
use crate::device::DeviceProfile;

/// Analytic stage-time model: the closed-form version of what the
/// engines pace (used for fast corpus-wide sweeps; the engine path
/// validates it on a subset — see `tests/analysis_integration.rs`).
pub fn analytic_stage_times(cfg: &BenchConfig, p: &DeviceProfile) -> crate::analysis::StageTimes {
    let h2d = p.transfer_time(cfg.h2d_bytes as usize, true) + p.alloc_time(cfg.h2d_bytes as usize);
    let kex_per_iter = p.kex_time(cfg.flops_per_iteration());
    let kex = kex_per_iter * cfg.kex_iterations.max(1);
    let d2h = p.transfer_time(cfg.d2h_bytes as usize, false);
    crate::analysis::StageTimes { h2d, kex, d2h }
}
