//! Crate-wide error type.

use std::fmt;

/// Errors surfaced by the hetstream runtime.
#[derive(Debug)]
pub enum Error {
    /// Failure inside the XLA/PJRT layer.
    Xla(String),
    /// Artifact manifest problems (missing file, bad shapes, ...).
    Manifest(String),
    /// A kernel call whose inputs don't match the artifact signature.
    Signature { artifact: String, detail: String },
    /// Device-memory arena exhaustion or bad handle.
    Arena(String),
    /// Stream/engine machinery failure (disconnected queue, poisoned op).
    Stream(String),
    /// A malformed `StreamPlan` (forward dep, out-of-buffer region, ...).
    Plan(String),
    /// A submission the service refused at admission time (over-budget
    /// tenant, deadline-infeasible request) — load shedding, not a
    /// failure of the service itself.
    Admission { tenant: String, reason: String },
    /// Service-layer machinery failure (lane spawn, dropped ticket) —
    /// distinct from [`Error::Stream`], which is engine machinery.
    Service(String),
    /// A malformed or inconsistent [`crate::spec::WorkloadSpec`]
    /// (unparsable JSON, missing buffer, unknown kernel, size
    /// mismatch, ...) — rejected before any lowering happens.
    Spec(String),
    /// Configuration / CLI errors.
    Config(String),
    /// I/O (manifest and artifact loading).
    Io(std::io::Error),
}

pub type Result<T> = std::result::Result<T, Error>;

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Xla(m) => write!(f, "xla error: {m}"),
            Error::Manifest(m) => write!(f, "manifest error: {m}"),
            Error::Signature { artifact, detail } => {
                write!(f, "signature mismatch for artifact `{artifact}`: {detail}")
            }
            Error::Arena(m) => write!(f, "device arena error: {m}"),
            Error::Stream(m) => write!(f, "stream error: {m}"),
            Error::Plan(m) => write!(f, "plan error: {m}"),
            Error::Admission { tenant, reason } => {
                write!(f, "admission rejected for tenant `{tenant}`: {reason}")
            }
            Error::Spec(m) => write!(f, "spec error: {m}"),
            Error::Service(m) => write!(f, "service error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

impl From<crate::util::json::JsonError> for Error {
    fn from(e: crate::util::json::JsonError) -> Self {
        Error::Manifest(e.to_string())
    }
}
