#!/usr/bin/env python3
"""Offline mirror of the hetstream joint-tuner stack.

Mirrors, in plain Python, the exact virtual-clock semantics of the Rust
runtime for descriptor-backed corpus plans:

  corpus descriptors  ->  lower_corpus_{bulk,streamed_at}  ->  executor
  placement (lane % n, FIFO DMA lanes, one kernel worker)  ->  the
  discrete-event timeline (start = max(lane avail, deps end)).

On top of that it mirrors the tuning algorithms this PR adds —
`predict_plan_point` (with the degenerate-profile fix), the
seed-centered pruned search (`autotune_plan_pruned`), `PlanFeatures`,
and the distance-weighted k-NN learned tuner with leave-one-app-out
cross-validation — so their behavior can be validated end-to-end
without a Rust toolchain (none exists in this container).

The corpus tables are parsed straight out of the Rust sources, so the
mirror cannot drift from the descriptors.

Run:  python3 tools/mirror/tuner_mirror.py [--apps N]
"""

import argparse
import json
import math
import os
import re
import sys

RUST = os.path.join(os.path.dirname(__file__), "..", "..", "rust", "src")

# --- device profile (mic31sp, dilated 16x — ContextBuilder default) ----

DILATION = 16.0


class Profile:
    def __init__(self, h2d_gbps, d2h_gbps, latency_us, alloc_us_per_mb,
                 gflops, launch_us):
        self.h2d_gbps = h2d_gbps
        self.d2h_gbps = d2h_gbps
        self.latency_us = latency_us
        self.alloc_us_per_mb = alloc_us_per_mb
        self.gflops = gflops
        self.launch_us = launch_us

    def transfer_ns(self, nbytes, h2d):
        bw = self.h2d_gbps if h2d else self.d2h_gbps
        secs = self.latency_us * 1e-6 + nbytes / (bw * 1e9)
        return round(max(secs, 0.0) * 1e9)

    def alloc_ns(self, nbytes):
        mb = nbytes / (1024.0 * 1024.0)
        return round(max(self.alloc_us_per_mb * mb * 1e-6, 0.0) * 1e9)

    def kex_ns(self, flops):
        secs = self.launch_us * 1e-6 + flops / (self.gflops * 1e9)
        return round(max(secs, 0.0) * 1e9)


def mic31sp_sim():
    return Profile(6.0 / DILATION, 6.5 / DILATION, 15.0 * DILATION,
                   70.0 * DILATION, 22.0 / DILATION, 8.0 * DILATION)


# --- corpus parsing ----------------------------------------------------

CATS = ("Sync", "Iterative", "Independent", "FalseDependent", "TrueDependent")


class Cfg:
    def __init__(self, suite, app, label, h2d_mb, d2h_mb, mflop, iters, facts):
        self.suite = suite
        self.app = app
        self.config = label
        self.h2d_bytes = int(h2d_mb * 1024.0 * 1024.0)
        self.d2h_bytes = int(d2h_mb * 1024.0 * 1024.0)
        self.flops = int(mflop * 1e6) * iters
        self.kex_iterations = iters
        self.facts = facts  # dict: sync, iterative, sequential, dep, halo, chunk

    def category(self):
        f = self.facts
        if f["sync"]:
            return "Sync"
        if f["iterative"] or f["sequential"]:
            return "Iterative"
        return {"None": "Independent", "Rar": "FalseDependent",
                "Raw": "TrueDependent"}[f["dep"]]

    def flops_per_iteration(self):
        return self.flops // max(self.kex_iterations, 1)


def parse_corpus():
    cfgs = []
    for fname in ("rodinia.rs", "parboil.rs", "nvidia.rs", "amd.rs"):
        src = open(os.path.join(RUST, "corpus", fname)).read()
        suite = fname[:-3]
        # Normalize the one multi-line mk(...) form (myocyte).
        src = re.sub(r"\s+", " ", src)
        for m in re.finditer(
                r'mk\(\s*s,\s*"([^"]+)",\s*(DependencyFacts.*?),\s*'
                r'Backing::[^,]+,\s*&\[(.*?)\]\s*,?\s*\)', src):
            app, facts_src, rows_src = m.groups()
            facts = {"sync": False, "iterative": False, "sequential": False,
                     "dep": "None", "halo": 0, "chunk": 0}
            if "::sync()" in facts_src:
                facts["sync"] = True
            elif "::iterative()" in facts_src:
                facts["iterative"] = True
            elif "sequential_kernel: true" in facts_src:
                facts["sequential"] = True
            elif "::raw()" in facts_src:
                facts["dep"] = "Raw"
            elif "::rar(" in facts_src:
                facts["dep"] = "Rar"
                h, c = re.search(r"::rar\(([^,]+),\s*([^)]+)\)", facts_src).groups()
                facts["halo"] = int(eval(h))  # handles `1 << 20`
                facts["chunk"] = int(eval(c))
            for r in re.finditer(
                    r'\("([^"]+)",\s*([\d.]+),\s*([\d.]+),\s*([\d.]+),\s*(\d+)\)',
                    rows_src):
                label, h2d, d2h, mflop, iters = r.groups()
                cfgs.append(Cfg(suite, app, label, float(h2d), float(d2h),
                                float(mflop), int(iters), facts))
    return cfgs


def representative(cfgs):
    seen, out = set(), []
    for c in cfgs:
        if (c.app, c.suite) not in seen:
            seen.add((c.app, c.suite))
            out.append(c)
    return out


# --- lowering mirror (plan/lower.rs) -----------------------------------

KEX_BYTES = 65536 * 4
CORPUS_TASKS = 8
WAVEFRONT_GRID = 4


class Scaled:
    def __init__(self, c):
        self.h2d = max(int(c.h2d_bytes / DILATION), 4)
        self.d2h = max(int(c.d2h_bytes / DILATION), 4)
        self.flops_per_iter = min(int(c.flops_per_iteration() / DILATION),
                                  300_000_000)
        self.repeats = min(max(c.kex_iterations, 1), 20)


def default_gran(cat):
    if cat in ("Independent", "FalseDependent"):
        return CORPUS_TASKS
    if cat == "TrueDependent":
        return WAVEFRONT_GRID
    return 1


def effective_gran(c, g):
    g = max(g, 1)
    cat = c.category()
    if cat in ("Sync", "Iterative"):
        return 1
    if cat in ("Independent", "FalseDependent"):
        s = Scaled(c)
        return max(min(g, max(s.h2d, 4) // 4), 1)
    return min(max(g, 1), 8)


class Op:
    __slots__ = ("kind", "lane", "deps", "dur_bytes", "flops", "buf",
                 "reads", "writes")

    def __init__(self, kind, lane, deps, dur_bytes=0, flops=0, buf=-1,
                 reads=None, writes=None):
        self.kind = kind      # 'h2d' | 'kex' | 'd2h'
        self.lane = lane      # Slot lane (task index / diagonal slot)
        self.deps = deps      # indices of earlier ops
        self.dur_bytes = dur_bytes
        self.flops = flops    # already includes repeats
        self.buf = buf        # destination buffer for h2d (alloc tracking)
        # Byte-interval access records for the NativeBackend output-path
        # check: lists of (space, id, lo, hi) with space 'dev' | 'out'.
        self.reads = reads or []
        self.writes = writes or []


def lane_up(n):
    return (n + 3) & ~3


def lower_bulk(c):
    s = Scaled(c)
    ops = [Op("h2d", 0, [], dur_bytes=s.h2d, buf=0,
              writes=[("dev", 0, 0, s.h2d)])]
    ops.append(Op("kex", 0, [], flops=s.flops_per_iter * max(s.repeats, 1),
                  reads=[("dev", 0, 0, KEX_BYTES)],
                  writes=[("dev", 1, 0, KEX_BYTES)]))
    ops.append(Op("d2h", 0, [1], dur_bytes=s.d2h,
                  reads=[("dev", 1, 0, s.d2h)],
                  writes=[("out", 0, 0, s.d2h)]))
    return ops


def diagonals(g):
    out = []
    for d in range(2 * g - 1):
        out.append([(bi, d - bi) for bi in range(max(0, d - (g - 1)),
                                                 min(d, g - 1) + 1)])
    return out


def lower_streamed_at(c, gran):
    s = Scaled(c)
    eff = effective_gran(c, gran)
    cat = c.category()
    if cat in ("Sync", "Iterative"):
        return lower_bulk(c)
    if cat == "TrueDependent":
        return lower_tasks(c, s, eff * eff, 0.0, eff)
    inflate = 0.0
    if c.facts["dep"] == "Rar":
        inflate = 2.0 * c.facts["halo"] / max(c.facts["chunk"], 1)
    return lower_tasks(c, s, eff, inflate, None)


def lower_tasks(c, s, m, inflate, wavefront):
    h, d = s.h2d, s.d2h
    ops = []
    nbuf = [0]

    def new_buf():
        nbuf[0] += 1
        return nbuf[0] - 1

    ix = [(t * h // m) & ~3 for t in range(m)] + [h]
    ob = [min(ix[t], d) for t in range(m)] + [d]
    zmax = max((ob[t + 1] - max(ob[t], KEX_BYTES) for t in range(m)
                if ob[t + 1] > max(ob[t], KEX_BYTES)), default=0)
    zeros = new_buf() if zmax > 0 else -1  # never-written zero source
    flops = s.flops_per_iter // m

    def emit(t, slot, deps):
        olo, ohi = ob[t], ob[t + 1]
        ilo, ihi = ix[t], ix[t + 1]
        halo = 0
        if inflate > 0.0 and ihi > ilo:
            halo = lane_up(max(int((ihi - ilo) * inflate / 2.0), 1))
        xlo = ilo - min(halo, ilo)
        xhi = min(ihi + halo, h)
        xfer = xhi - xlo
        in_buf = new_buf()
        out_buf = new_buf()  # kex-written; no alloc charge
        if xfer > 0:
            ops.append(Op("h2d", slot, [], dur_bytes=xfer, buf=in_buf,
                          writes=[("dev", in_buf, 0, xfer)]))
        kex = len(ops)
        ops.append(Op("kex", slot, deps, flops=flops * max(s.repeats, 1),
                      reads=[("dev", in_buf, 0, KEX_BYTES)],
                      writes=[("dev", out_buf, 0, KEX_BYTES)]))
        chi = min(ohi, KEX_BYTES)
        if chi > olo:
            delta = olo - xlo
            ops.append(Op("d2h", slot, [kex], dur_bytes=chi - olo,
                          reads=[("dev", out_buf, delta, delta + chi - olo)],
                          writes=[("out", 0, olo, chi)]))
        zlo = max(olo, KEX_BYTES)
        if ohi > zlo:
            ops.append(Op("d2h", slot, [], dur_bytes=ohi - zlo,
                          reads=[("dev", zeros, 0, ohi - zlo)],
                          writes=[("out", 0, zlo, ohi)]))
        return kex

    if wavefront is not None:
        g = wavefront
        kex_ids = {}
        for diag in diagonals(g):
            for slot, (bi, bj) in enumerate(diag):
                deps = []
                if bi > 0:
                    deps.append(kex_ids[(bi - 1, bj)])
                if bj > 0:
                    deps.append(kex_ids[(bi, bj - 1)])
                if bi > 0 and bj > 0:
                    deps.append(kex_ids[(bi - 1, bj - 1)])
                kex_ids[(bi, bj)] = emit(bi * g + bj, slot, deps)
    else:
        for t in range(m):
            emit(t, t, [])
    return ops


# --- executor + virtual clock mirror -----------------------------------

def simulate(ops, n, profile):
    """Makespan (ns) of `ops` mapped onto n streams, lanes quiesced at 0."""
    n = max(n, 1)
    lane_avail = {"h2d": 0, "d2h": 0, "kex": 0}
    stream_last = {}
    touched = set()
    ends = []
    starts = []
    for op in ops:
        stream = op.lane % n
        deps_end = stream_last.get(stream, 0)
        for didx in op.deps:
            deps_end = max(deps_end, ends[didx])
        if op.kind == "h2d":
            dur = profile.transfer_ns(op.dur_bytes, True)
            if op.buf not in touched:
                touched.add(op.buf)
                dur += profile.alloc_ns(op.dur_bytes)
        elif op.kind == "d2h":
            dur = profile.transfer_ns(op.dur_bytes, False)
        else:
            dur = profile.kex_ns(op.flops)
        start = max(lane_avail[op.kind], deps_end)
        end = start + dur
        lane_avail[op.kind] = end
        stream_last[stream] = end
        starts.append(start)
        ends.append(end)
    return (max(ends) - min(starts)) / 1e6  # ms


def stage_times_ns(ops, profile):
    h2d = kex = d2h = 0
    touched = set()
    for op in ops:
        if op.kind == "h2d":
            h2d += profile.transfer_ns(op.dur_bytes, True)
            if op.buf not in touched:
                touched.add(op.buf)
                h2d += profile.alloc_ns(op.dur_bytes)
        elif op.kind == "kex":
            kex += profile.kex_ns(op.flops)
        else:
            d2h += profile.transfer_ns(op.dur_bytes, False)
    return h2d, kex, d2h


# --- NativeBackend output-path check ------------------------------------
#
# The Rust `plan::NativeBackend` runs the task DAG on a host thread
# pool in ANY topological order of the backend dependency contract
# (explicit deps + per-lane program order; broadcast ops don't occur in
# corpus lowerings).  Its outputs are bitwise-identical to the engine
# path iff, under that partial order:
#
#   1. every pair of ops touching overlapping byte intervals, at least
#      one writing, is ordered (no data race any schedule could expose);
#   2. the D2H writes tile each host output exactly once (so assembly
#      is schedule-independent), with the same total extent as bulk.
#
# This mirrors those two properties over every corpus lowering at
# several granularities — the offline twin of the Rust-side
# `sim_and_native_backends_assemble_identical_bytes` bitwise test.


def native_deps(ops):
    """Full dep lists under the backend contract: explicit deps plus
    program order within each Slot lane (mirrors plan/backend.rs)."""
    deps = []
    last = {}
    for i, op in enumerate(ops):
        d = set(op.deps)
        if op.lane in last:
            d.add(last[op.lane])
        last[op.lane] = i
        deps.append(sorted(d))
    return deps


def native_output_path_check(c, gran):
    ops = lower_streamed_at(c, gran)
    deps = native_deps(ops)
    # Ancestor bitsets over the dependency closure (ops are in
    # topological order by construction).
    anc = []
    for i, d in enumerate(deps):
        a = 0
        for p in d:
            a |= anc[p] | (1 << p)
        anc.append(a)

    def ordered(i, j):
        return bool(anc[j] >> i & 1) or bool(anc[i] >> j & 1)

    # 1. Conflict-freedom per buffer/output.
    accesses = {}
    for i, op in enumerate(ops):
        for space, bid, lo, hi in op.reads:
            accesses.setdefault((space, bid), []).append((i, lo, hi, False))
        for space, bid, lo, hi in op.writes:
            accesses.setdefault((space, bid), []).append((i, lo, hi, True))
    for (space, bid), accs in accesses.items():
        for k in range(len(accs)):
            i, lo_i, hi_i, w_i = accs[k]
            for j, lo_j, hi_j, w_j in accs[k + 1:]:
                if i == j or (not w_i and not w_j):
                    continue
                if lo_i < hi_j and lo_j < hi_i and not ordered(i, j):
                    raise AssertionError(
                        f"{c.app}/{c.config} gran {gran}: unordered conflict "
                        f"on {space}{bid} between op {i} and op {j}")

    # 2. Output writes tile [0, d2h) exactly once, matching bulk.
    wins = sorted((lo, hi) for op in ops for space, _, lo, hi in op.writes
                  if space == "out")
    d = Scaled(c).d2h
    pos = 0
    for lo, hi in wins:
        assert lo == pos and hi > lo, (
            f"{c.app}/{c.config} gran {gran}: output gap/overlap at {lo} "
            f"(expected {pos})")
        pos = hi
    assert pos == d, f"{c.app}/{c.config} gran {gran}: covered {pos} of {d}"


def native_check(apps):
    checked = 0
    for c in apps:
        for g in (1, default_gran(c.category()), 7, 16):
            native_output_path_check(c, g)
            checked += 1
    print(f"native output-path check: OK ({checked} (app, granularity) "
          f"plans: conflicts ordered, outputs tiled exactly once)")


def native_verdicts(apps):
    """Per-(app, config, granularity) verdict rows for the CI
    cross-check against `repro verify --corpus --json`.  Both sides key
    on the *requested* granularity (1, category default, 7, 16 —
    pre-clamp, duplicates kept) so the verdict lists align 1:1 over the
    same 224-plan population."""
    rows = []
    for c in apps:
        for g in (1, default_gran(c.category()), 7, 16):
            try:
                native_output_path_check(c, g)
                err = None
            except AssertionError as e:
                err = str(e)
            rows.append({"app": c.app, "config": c.config, "gran": g,
                         "ok": err is None, "error": err})
    return rows


# --- arena must-zero mirror (rust/src/runtime/arena.rs twin) -----------
#
# The NativeBackend reuses pooled arenas across runs, clearing only the
# plan's *must-zero* spans (bytes some op reads that no earlier op
# wrote) at checkout; every other byte is stale leftovers from the
# previous plan.  This mirror re-derives the span analysis from the
# lowering's byte-interval access records and replays every plan over a
# deliberately dirty (0xAB) arena: any read that could observe a stale
# byte is a hole in the analysis.  Index-order replay is exact because
# the conflict check above proves every overlapping read/write pair is
# ordered, and deps point strictly backwards — so the writes a read can
# observe are exactly the writes at smaller indices.

def _ivl_insert(ivls, lo, hi):
    """Insert [lo, hi) into a sorted disjoint list, merging touching."""
    if lo >= hi:
        return
    keep = []
    for a, b in ivls:
        if b < lo or a > hi:
            keep.append((a, b))
        else:
            lo, hi = min(lo, a), max(hi, b)
    keep.append((lo, hi))
    keep.sort()
    ivls[:] = keep


def _ivl_uncovered(ivls, lo, hi):
    """The parts of [lo, hi) not covered by any interval."""
    out = []
    cur = lo
    for a, b in sorted(ivls):
        if b <= cur:
            continue
        if a >= hi:
            break
        if a > cur:
            out.append((cur, min(a, hi)))
        cur = max(cur, b)
        if cur >= hi:
            break
    if cur < hi:
        out.append((cur, hi))
    return out


def arena_zero_spans(ops):
    """Must-zero spans per dev buffer, scanning in op (index) order —
    the twin of ArenaLayout::of."""
    written = {}
    zero = {}
    for op in ops:
        for space, bid, lo, hi in op.reads:
            if space != "dev":
                continue
            for s, e in _ivl_uncovered(written.get(bid, []), lo, hi):
                _ivl_insert(zero.setdefault(bid, []), s, e)
        for space, bid, lo, hi in op.writes:
            if space != "dev":
                continue
            _ivl_insert(written.setdefault(bid, []), lo, hi)
    return zero


def arena_replay_check(c, gran, clear=True):
    """Replay one lowering over a dirty 0xAB arena with only the
    must-zero spans cleared; returns the number of cleared spans.
    Raises if any op reads a byte that is still stale."""
    STALE, DEFINED = 0xAB, 0x01
    ops = lower_streamed_at(c, gran)
    extent = {}
    for op in ops:
        for space, bid, lo, hi in op.reads + op.writes:
            if space == "dev":
                extent[bid] = max(extent.get(bid, 0), hi)
    arena = {bid: bytearray([STALE] * n) for bid, n in extent.items()}
    zero = arena_zero_spans(ops)
    spans = 0
    if clear:
        for bid, ivls in zero.items():
            for lo, hi in ivls:
                arena[bid][lo:hi] = bytes(hi - lo)
                spans += 1
    for i, op in enumerate(ops):
        for space, bid, lo, hi in op.reads:
            if space == "dev" and STALE in arena[bid][lo:hi]:
                raise AssertionError(
                    f"{c.app}/{c.config} gran {gran}: op {i} reads stale "
                    f"arena bytes in dev{bid}[{lo}:{hi})")
        for space, bid, lo, hi in op.writes:
            if space == "dev":
                arena[bid][lo:hi] = bytes([DEFINED] * (hi - lo))
    return spans


def arena_check(apps):
    checked = spans = 0
    dirty_witness = None
    for c in apps:
        for g in (1, default_gran(c.category()), 7, 16):
            n = arena_replay_check(c, g)
            if n > 0 and dirty_witness is None:
                dirty_witness = (c, g)
            checked += 1
            spans += n
    # The check must have teeth: a zero-source plan replayed WITHOUT
    # clearing its must-zero spans has to trip the stale-read assert.
    assert dirty_witness is not None, \
        "no corpus plan exercises a must-zero span — the replay is vacuous"
    c, g = dirty_witness
    try:
        arena_replay_check(c, g, clear=False)
    except AssertionError:
        pass
    else:
        raise AssertionError(
            f"{c.app} gran {g}: uncleared dirty arena must fail the replay")
    print(f"arena must-zero replay: OK ({checked} (app, granularity) plans "
          f"over dirty 0xAB arenas, {spans} span(s) cleared, "
          f"negative control trips)")


# --- analytic seed (with the degenerate-profile fix) -------------------

GRAN_CEILING = 64


def predict_streams(h2d, kex, d2h):
    total = h2d + kex + d2h
    bottleneck = max(h2d, kex, d2h)
    if bottleneck <= 0:
        return 2
    return min(max(math.ceil(total / bottleneck) + 1, 2), 8)


def predict_plan_point(ops, profile):
    h2d, kex, d2h = stage_times_ns(ops, profile)
    streams = predict_streams(h2d, kex, d2h)
    bottleneck = max(h2d, kex, d2h)
    c_task = (profile.launch_us if bottleneck == kex else profile.latency_us) * 1e-6
    overlappable = (h2d + kex + d2h - bottleneck) / 1e9
    if overlappable <= 0.0:
        gran = streams
    elif c_task <= 0.0:
        gran = GRAN_CEILING
    else:
        gran = min(max(int(round(math.sqrt(overlappable / c_task))), 1),
                   GRAN_CEILING)
    return streams, max(gran, streams)


def gran_ladder(seed):
    s = min(max(seed, 1), 64)
    return sorted(set([1, 2, 4, 8, 16, max(s // 2, 1), s, min(s * 2, 64)]))


# --- full grid + pruned search -----------------------------------------

def argmin_first(points):
    best = None
    for k, v in points:
        if best is None or (not math.isnan(v) and (math.isnan(best[1]) or v < best[1])):
            best = (k, v)
    return best


def candidate_grans(c, seed_gran, user=(1, 2, 4, 8, 16)):
    fixed = effective_gran(c, default_gran(c.category()))
    grans = sorted(set(effective_gran(c, g)
                       for g in list(user) + gran_ladder(seed_gran) + [fixed]))
    return grans, fixed


def full_grid(c, streams, grans, profile):
    surface = {}
    for g in grans:
        ops = lower_streamed_at(c, g)
        for n in streams:
            surface[(n, g)] = simulate(ops, n, profile)
    best = argmin_first(sorted(surface.items(), key=lambda kv: (kv[0][1], kv[0][0])))
    return surface, best


NEIGHBORHOOD = ((1, 0), (-1, 0), (0, 1), (0, -1))


def pruned_search(c, streams, grans, seed, profile):
    """Hill-climb the measured surface outward from the (snapped) seed:
    measure the current point's 4-neighborhood in (stream, gran) index
    space, move to the best measured point so far, stop when the
    current point beats every measured neighbor."""
    sseed, gseed = seed
    si = min(range(len(streams)), key=lambda i: abs(streams[i] - sseed))
    gi = min(range(len(grans)),
             key=lambda i: abs(math.log((grans[i] + 0.5) / (gseed + 0.5))))
    cache = {}
    plans = {}

    def measure(i, j):
        key = (streams[i], grans[j])
        if key not in cache:
            if grans[j] not in plans:
                plans[grans[j]] = lower_streamed_at(c, grans[j])
            cache[key] = simulate(plans[grans[j]], streams[i], profile)
        return cache[key]

    measure(si, gi)
    for _ in range(len(streams) * len(grans)):
        for ds, dg in NEIGHBORHOOD:
            i, j = si + ds, gi + dg
            if 0 <= i < len(streams) and 0 <= j < len(grans):
                measure(i, j)
        (bs, bg), _ = argmin_first(sorted(cache.items()))
        bi, bj = streams.index(bs), grans.index(bg)
        if (bi, bj) == (si, gi):
            break
        si, gi = bi, bj
    best = argmin_first(sorted(cache.items()))
    return cache, best


# --- features + k-NN ----------------------------------------------------

def features(c, profile):
    ops = lower_streamed_at(c, default_gran(c.category()))
    h2d, kex, d2h = stage_times_ns(ops, profile)
    total = max(h2d + kex + d2h, 1)
    tasks = sum(1 for op in ops if op.kind == "kex")
    # DAG depth over explicit kex deps.
    depth = {}
    maxd = 1
    for i, op in enumerate(ops):
        if op.kind != "kex":
            continue
        d = 1 + max((depth.get(j, 0) for j in op.deps), default=0)
        depth[i] = d
        maxd = max(maxd, d)
    width = max((sum(1 for v in depth.values() if v == d)
                 for d in range(1, maxd + 1)), default=1)
    h2d_bytes = sum(op.dur_bytes for op in ops if op.kind == "h2d")
    d2h_bytes = sum(op.dur_bytes for op in ops if op.kind == "d2h")
    flops = sum(op.flops for op in ops if op.kind == "kex")
    cat = c.category()
    onehot = [1.0 if cat == k else 0.0 for k in
              ("Independent", "FalseDependent", "TrueDependent")]
    nonstream = 1.0 if cat in ("Sync", "Iterative") else 0.0
    return onehot + [
        nonstream,
        math.log10(tasks + 1) / 2.0,
        maxd / max(tasks, 1),
        width / max(tasks, 1),
        math.log10(h2d_bytes + 1) / 9.0,
        math.log10(d2h_bytes + 1) / 9.0,
        math.log10(flops + 1) / 12.0,
        h2d / total,
        kex / total,
        d2h / total,
    ]


def knn_predict(train, feats, cat, k=5):
    """train: list of (features, category, best_streams, best_gran_tasks)."""
    neigh = [(sum((a - b) ** 2 for a, b in zip(f, feats)) ** 0.5, s, g)
             for (f, c2, s, g) in train if c2 == cat]
    if not neigh:
        return None
    neigh.sort(key=lambda t: t[0])
    neigh = neigh[:k]
    wsum = sum(1.0 / (d + 1e-6) for d, _, _ in neigh)
    ls = sum(math.log(s) / (d + 1e-6) for d, s, _ in neigh) / wsum
    lg = sum(math.log(g) / (d + 1e-6) for d, _, g in neigh) / wsum
    # No upper stream clamp (matches KnnTuner::predict): the vote stays
    # within the training labels' range and callers snap onto ladders.
    return (max(int(round(math.exp(ls))), 1),
            max(int(round(math.exp(lg))), 1))


# --- experiments --------------------------------------------------------

def golden_trace_check():
    """Replay rust/tests/golden/fig1_pipeline_trace.json's scenario and
    compare every interval — validates the clock/lane semantics of
    `simulate` against the hand-verified Rust timeline."""
    p = Profile(1.0, 1.0, 0.0, 0.0, 1.0, 0.0)
    ops = []
    for c in range(4):
        ops.append(Op("h2d", c, [], dur_bytes=262144, buf=3 * c))
        ops.append(Op("h2d", c, [], dur_bytes=262144, buf=3 * c + 1))
        ops.append(Op("kex", c, [], flops=1_000_000))
        ops.append(Op("d2h", c, [], dur_bytes=262144))
    # Re-run simulate but capture intervals.
    lane_avail = {"h2d": 0, "d2h": 0, "kex": 0}
    stream_last = {}
    got = []
    ends = []
    for op in ops:
        stream = op.lane % 2
        deps_end = stream_last.get(stream, 0)
        for d in op.deps:
            deps_end = max(deps_end, ends[d])
        dur = (p.kex_ns(op.flops) if op.kind == "kex"
               else p.transfer_ns(op.dur_bytes, op.kind == "h2d"))
        start = max(lane_avail[op.kind], deps_end)
        end = start + dur
        lane_avail[op.kind] = end
        stream_last[stream] = end
        ends.append(end)
        got.append((start, end))
    golden = [(0, 262144), (262144, 524288), (524288, 1524288),
              (1524288, 1786432), (524288, 786432), (786432, 1048576),
              (1524288, 2524288), (2524288, 2786432), (1786432, 2048576),
              (2048576, 2310720), (2524288, 3524288), (3524288, 3786432),
              (2786432, 3048576), (3048576, 3310720), (3524288, 4524288),
              (4524288, 4786432)]
    assert got == golden, f"golden trace mismatch:\n{got}\nvs\n{golden}"
    print("golden-trace check: OK (16/16 intervals match the Rust timeline)")


# --- spec front-end cross-check ----------------------------------------
#
# An independent Python derivation of `SpecCompiler::windows_at`
# (rust/src/spec/compile.rs), diffed op-for-op against a
# `repro run-spec FILE --json` dump.  Only windows-mode specs are
# supported; the corpus modes are already covered by the descriptor
# mirror above.

# Elastic kernels accept any whole-lane window (runtime::elastic_artifact).
SPEC_ELASTIC = {"vector_add", "black_scholes", "nn_dist"}

# Fixed-shape pipeline kernels the mirror knows the input-tile bytes of
# (rust/src/runtime/manifest.rs is the source of truth).
SPEC_FIXED_TILE = {"fwt": 16384}


def spec_elastic(kernel):
    return kernel in SPEC_ELASTIC or kernel.startswith("burner_")


def spec_halo_side(ratio, length):
    """Halo bytes for one window side: ratio x window, lane-aligned,
    at least one lane when the ratio is non-zero (compile.rs
    halo_side; `as usize` truncates, like int())."""
    if ratio > 0.0 and length > 0:
        return lane_up(max(int(length * ratio), 1))
    return 0


def spec_window_quantum(spec):
    q = 4
    for st in spec["stages"]:
        k = st["kernel"]
        if spec_elastic(k):
            continue
        if k not in SPEC_FIXED_TILE:
            sys.exit(f"spec-check: unknown fixed-shape kernel {k!r} "
                     f"(teach SPEC_FIXED_TILE its tile size)")
        q = max(q, SPEC_FIXED_TILE[k])
    return q


def lower_spec_windows(spec, m):
    """Port of SpecCompiler::windows_at(m): the op list in exactly the
    shape `run_spec_json` dumps (buffer ids in allocation order, RAW
    deps by op index, owned-range downloads)."""
    h = spec["buffers"][0]["bytes"]
    halo = spec.get("halo") or {}
    halo_lo, halo_hi = halo.get("lo", 0.0), halo.get("hi", 0.0)
    q = spec_window_quantum(spec)
    n_payloads = len(spec["stages"][0]["inputs"])
    ops = []
    nbuf = [0]

    def new_buf():
        nbuf[0] += 1
        return nbuf[0] - 1

    def region(buf, off, length):
        return {"buf": buf, "off": off, "len": length}

    ix = [(t * h // m) // q * q for t in range(m)] + [h]
    for t in range(m):
        ilo, ihi = ix[t], ix[t + 1]
        if ihi == ilo:
            continue  # more tasks than quanta: this lane is empty
        length = ihi - ilo
        hlo = spec_halo_side(halo_lo, length)
        hhi = spec_halo_side(halo_hi, length)
        xlo = ilo - min(hlo, ilo)
        xhi = min(ihi + hhi, h)
        xfer = xhi - xlo

        in_bufs = [new_buf() for _ in range(n_payloads)]
        for buf in in_bufs:
            ops.append({"kind": "h2d", "slot": t, "deps": [],
                        "bytes": xfer, "buf": buf, "off": 0})

        stage_in = in_bufs
        prev_kex = []
        for st in spec["stages"]:
            flops = st.get("flops")
            if flops is not None:
                flops = flops * length // h
            out_buf = new_buf()
            if spec_elastic(st["kernel"]):
                kex = len(ops)
                ops.append({"kind": "kex", "slot": t, "deps": prev_kex,
                            "artifact": st["kernel"],
                            "inputs": [region(b, 0, xfer)
                                       for b in stage_in],
                            "outputs": [region(out_buf, 0, xfer)],
                            "flops": flops, "repeats": 1})
                prev_kex = [kex]
            else:
                tile = SPEC_FIXED_TILE[st["kernel"]]
                tiles = xfer // tile
                per_tile = (flops // max(tiles, 1)
                            if flops is not None else None)
                ids = []
                for j in range(tiles):
                    ids.append(len(ops))
                    ops.append({"kind": "kex", "slot": t,
                                "deps": prev_kex,
                                "artifact": st["kernel"],
                                "inputs": [region(stage_in[0],
                                                  j * tile, tile)],
                                "outputs": [region(out_buf,
                                                   j * tile, tile)],
                                "flops": per_tile, "repeats": 1})
                prev_kex = ids
            stage_in = [out_buf]

        delta = ilo - xlo
        ops.append({"kind": "d2h", "slot": t, "deps": prev_kex,
                    "bytes": length, "buf": stage_in[0], "off": delta,
                    "output": 0, "out_off": ilo})
    return ops


def spec_check(spec_path, dump_path):
    with open(spec_path) as f:
        spec = json.load(f)
    with open(dump_path) as f:
        dump = json.load(f)
    if spec.get("schema") != "hetstream-spec-v1":
        sys.exit("spec-check: not a hetstream-spec-v1 spec")
    if dump.get("schema") != "hetstream-run-spec-v1":
        sys.exit("spec-check: dump is not a hetstream-run-spec-v1 "
                 "document (run `repro run-spec FILE --json`)")
    if spec.get("mode") != "windows":
        sys.exit(f"spec-check: only windows-mode specs are supported "
                 f"(got {spec.get('mode')!r})")
    if dump.get("name") != spec.get("name"):
        sys.exit(f"spec-check: dump is for {dump.get('name')!r}, "
                 f"spec is {spec.get('name')!r}")
    gran = dump["gran"]
    h = spec["buffers"][0]["bytes"]
    eff = max(min(gran, max(h, 4) // 4), 1)
    if eff != gran:
        sys.exit(f"spec-check: dump gran {gran} is not a clamp "
                 f"fixpoint (expected {eff})")

    want = lower_spec_windows(spec, gran)
    got = dump["ops"]
    bad = 0
    if len(got) != len(want):
        print(f"spec-check: op count mismatch: rust {len(got)} vs "
              f"mirror {len(want)}")
        bad += 1
    for i, (g, w) in enumerate(zip(got, want)):
        if g != w:
            print(f"spec-check: op {i} mismatch:\n"
                  f"  rust:   {json.dumps(g, sort_keys=True)}\n"
                  f"  mirror: {json.dumps(w, sort_keys=True)}")
            bad += 1
            if bad >= 5:
                print("spec-check: (further mismatches suppressed)")
                break
    totals = dump.get("totals", {})
    derived = {
        "ops": len(want),
        "h2d_bytes": sum(o["bytes"] for o in want if o["kind"] == "h2d"),
        "d2h_bytes": sum(o["bytes"] for o in want if o["kind"] == "d2h"),
    }
    for key, val in derived.items():
        if totals.get(key) != val:
            print(f"spec-check: totals.{key} mismatch: rust "
                  f"{totals.get(key)} vs mirror {val}")
            bad += 1
    if bad:
        sys.exit(1)
    print(f"spec-check: OK ({spec['name']}: {len(want)} op(s) at gran "
          f"{gran} match the Rust lowering)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--apps", type=int, default=0, help="limit app count")
    ap.add_argument("--native-check", action="store_true",
                    help="run only the NativeBackend output-path check "
                         "(fast; advisory in CI — the Rust verifier "
                         "`repro verify --corpus` owns this proof, and "
                         "the cross-check diffs the two verdict sets)")
    ap.add_argument("--json", action="store_true",
                    help="with --native-check: print the per-(app, "
                         "config, granularity) verdicts as one JSON "
                         "document (tools/verify_crosscheck.py input) "
                         "and nothing else on stdout")
    ap.add_argument("--arena-check", action="store_true",
                    help="run only the golden-trace check and the arena "
                         "must-zero replay (fast; gating in CI)")
    ap.add_argument("--spec-check", metavar="SPEC",
                    help="lower a windows-mode workload spec "
                         "(specs/*.json) independently and diff its op "
                         "list against a `repro run-spec --json` dump "
                         "(requires --spec-json; gating in CI)")
    ap.add_argument("--spec-json", metavar="DUMP",
                    help="with --spec-check: path to the Rust side's "
                         "hetstream-run-spec-v1 dump to diff against")
    args = ap.parse_args()
    if args.json and not args.native_check:
        ap.error("--json requires --native-check")
    if args.spec_check:
        if not args.spec_json:
            ap.error("--spec-check requires --spec-json")
        spec_check(args.spec_check, args.spec_json)
        return

    if not args.json:
        golden_trace_check()
    profile = mic31sp_sim()
    cfgs = parse_corpus()
    apps = representative(cfgs)
    assert len({(c.app, c.suite) for c in cfgs}) == 56, \
        f"parsed {len({(c.app, c.suite) for c in cfgs})} apps, want 56"
    assert len(cfgs) == 223, f"parsed {len(cfgs)} configs, want 223"
    if args.apps:
        apps = apps[:args.apps]

    if args.native_check:
        rows = native_verdicts(apps)
        failed = [r for r in rows if not r["ok"]]
        if args.json:
            print(json.dumps({"schema": "mirror-native-check-v1",
                              "rows": rows, "total": len(rows),
                              "failed": len(failed)}))
        else:
            print(f"native output-path check: "
                  f"{'OK' if not failed else 'FAIL'} ({len(rows)} "
                  f"(app, granularity) plans, {len(failed)} hazardous)")
            for r in failed:
                print(f"  {r['app']}/{r['config']} gran {r['gran']}: "
                      f"{r['error']}")
        if args.arena_check:
            arena_check(apps)
        if failed:
            sys.exit(1)
        return

    if args.arena_check:
        # The native output-path proof was demoted to advisory here:
        # the Rust verifier (`repro verify --corpus`, cross-checked
        # against `--native-check --json` by tools/verify_crosscheck.py
        # in CI) now owns it.  This gate covers what only the mirror
        # can prove: the golden traces and the dirty-arena replay.
        arena_check(apps)
        return

    native_check(apps)
    arena_check(apps)

    streams = [1, 2, 4, 8]

    # Pass 1: full grids + analytic seeds (the dataset).
    rows = []
    for c in apps:
        bulk = lower_bulk(c)
        sseed, tseed = predict_plan_point(bulk, profile)
        knob = math.ceil(math.sqrt(tseed)) if c.category() == "TrueDependent" else tseed
        gseed = effective_gran(c, knob)
        grans, fixed = candidate_grans(c, gseed)
        surface, ((bs, bg), bms) = full_grid(c, streams, grans, profile)
        rows.append(dict(c=c, seed=(sseed, gseed), grans=grans, fixed=fixed,
                         surface=surface, best=(bs, bg), best_ms=bms))

    # Pass 2: pruned search from the analytic seed.
    print("== pruned (analytic seed) vs full grid ==")
    mismatches, fracs = 0, []
    tot_visited = tot_grid = 0
    for r in rows:
        cache, ((ps, pg), pms) = pruned_search(
            r["c"], streams, r["grans"], r["seed"], profile)
        grid = len(streams) * len(r["grans"])
        frac = len(cache) / grid
        fracs.append(frac)
        tot_visited += len(cache)
        tot_grid += grid
        same_time = abs(pms - r["best_ms"]) < 1e-12
        if not same_time:
            mismatches += 1
            print(f"  MISMATCH {r['c'].app}: pruned ({ps},{pg}) {pms:.4f} "
                  f"vs full ({r['best'][0]},{r['best'][1]}) {r['best_ms']:.4f} "
                  f"(+{(pms / r['best_ms'] - 1) * 100:.2f}%)")
        r["pruned_frac"] = frac
        r["pruned_ms"] = pms
    print(f"  argmin-time matches: {len(rows) - mismatches}/{len(rows)}")
    print(f"  visited fraction: mean {sum(fracs)/len(fracs):.3f}, "
          f"max {max(fracs):.3f}, aggregate {tot_visited}/{tot_grid} = "
          f"{tot_visited/tot_grid:.3f}")

    # Pass 3: leave-one-app-out CV of the k-NN seed.
    print("== leave-one-app-out CV (k-NN seed) ==")
    dataset = [(features(r["c"], profile), r["c"].category(),
                r["best"][0], r["best"][1]) for r in rows]
    within, empty = 0, 0
    worst = []
    for i, r in enumerate(rows):
        train = dataset[:i] + dataset[i + 1:]
        pred = knn_predict(train, features(r["c"], profile), r["c"].category())
        if pred is None:
            empty += 1
            pred = r["seed"]  # analytic fallback
        ps = min(streams, key=lambda s: abs(s - pred[0]))
        pg = min(r["grans"], key=lambda g: abs(math.log((g + 0.5) / (pred[1] + 0.5))))
        t = r["surface"][(ps, pg)]
        ratio = t / r["best_ms"] if r["best_ms"] > 0 else 1.0
        if ratio <= 1.10:
            within += 1
        else:
            worst.append((ratio, r["c"].app, (ps, pg), r["best"]))
    print(f"  within 10% of grid optimum: {within}/{len(rows)} "
          f"({100.0 * within / len(rows):.1f}%); empty neighborhoods: {empty}")
    for ratio, app, pred, best in sorted(worst, reverse=True)[:10]:
        print(f"    {app}: predicted {pred} vs best {best} "
              f"(+{(ratio - 1) * 100:.1f}%)")

    # Pass 4: pruned search seeded by the k-NN prediction (the
    # `repro tune --corpus --learned` path / acceptance criterion).
    print("== pruned (learned seed) — acceptance criterion ==")
    within, fracs = 0, []
    tot_visited = tot_grid = 0
    for i, r in enumerate(rows):
        train = dataset[:i] + dataset[i + 1:]
        pred = knn_predict(train, features(r["c"], profile), r["c"].category())
        if pred is None:
            pred = r["seed"]
        else:
            # Rust's tune_one maps the predicted knob through the
            # category clamp before the walk snaps it onto the ladder.
            pred = (pred[0], effective_gran(r["c"], pred[1]))
        cache, (_, pms) = pruned_search(r["c"], streams, r["grans"], pred, profile)
        frac = len(cache) / (len(streams) * len(r["grans"]))
        fracs.append(frac)
        tot_visited += len(cache)
        tot_grid += len(streams) * len(r["grans"])
        if pms <= r["best_ms"] * 1.10 + 1e-12:
            within += 1
    print(f"  within 10% of exhaustive optimum: {within}/{len(rows)}")
    print(f"  measured fraction of grid: mean {sum(fracs)/len(fracs):.3f}, "
          f"max {max(fracs):.3f}, aggregate {tot_visited/tot_grid:.3f} "
          f"(criterion: <= 0.40)")

    # Degenerate-profile seed sanity (the predict_plan_point bugfix).
    print("== degenerate profiles ==")
    zero_latency = Profile(6.0 / DILATION, 6.5 / DILATION, 0.0, 0.0,
                           22.0 / DILATION, 0.0)
    instant = Profile(float("inf"), float("inf"), 0.0, 0.0, float("inf"), 0.0)
    c = next(r["c"] for r in rows if r["c"].category() == "Independent")
    s, g = predict_plan_point(lower_bulk(c), zero_latency)
    print(f"  zero-latency profile on {c.app}: seed ({s}, {g}) "
          f"(gran must be the {GRAN_CEILING} ceiling)")
    s2, g2 = predict_plan_point(lower_bulk(c), instant)
    print(f"  instant profile: seed ({s2}, {g2}) (finite, no NaN walk)")


if __name__ == "__main__":
    main()
