#!/usr/bin/env python3
"""Cross-check the Rust hazard verifier against the Python mirror.

Two independently-implemented static analyses prove the same property
over the same 224-plan population (56 representative corpus apps x the
(1, category-default, 7, 16) granularity ladder):

  rust:   repro verify --corpus --json            > rust.json
  mirror: tools/mirror/tuner_mirror.py \\
              --native-check --json               > mirror.json
  diff:   tools/verify_crosscheck.py rust.json mirror.json

The check demands (a) both sides enumerated exactly the same
(app, config, granularity) keys, (b) every per-key verdict agrees, and
(c) every verdict is clean — any hazard one analysis sees and the other
does not is an implementation bug in one of them, and any agreed-upon
hazard is a corpus regression.  Exits non-zero on all three.
"""

import json
import sys


def rust_rows(doc):
    return {(r["app"], r["config"], int(r["gran"])): bool(r["ok"])
            for r in doc["rows"]}


def mirror_rows(doc):
    return {(r["app"], r["config"], int(r["gran"])): bool(r["ok"])
            for r in doc["rows"]}


def main():
    if len(sys.argv) != 3:
        sys.exit(f"usage: {sys.argv[0]} <rust-verify.json> <mirror.json>")
    with open(sys.argv[1]) as f:
        rust_doc = json.load(f)
    with open(sys.argv[2]) as f:
        mirror_doc = json.load(f)
    assert rust_doc.get("schema") == "hetstream-verify-v1", \
        f"unexpected rust schema {rust_doc.get('schema')!r}"
    assert mirror_doc.get("schema") == "mirror-native-check-v1", \
        f"unexpected mirror schema {mirror_doc.get('schema')!r}"

    # Both sides keep ladder duplicates (e.g. SYNC apps, whose default
    # granularity is 1, list gran 1 twice).  A duplicate key is the
    # same deterministic computation, so keyed dicts suffice for the
    # verdict diff — the raw row counts below catch a side that
    # enumerated a different population size.
    rust = sorted((k, v) for k, v in rust_rows(rust_doc).items())
    mirror = sorted((k, v) for k, v in mirror_rows(mirror_doc).items())
    rust_n, mirror_n = len(rust_doc["rows"]), len(mirror_doc["rows"])

    failures = []
    if rust_n != mirror_n:
        failures.append(f"population mismatch: rust {rust_n} rows, "
                        f"mirror {mirror_n}")
    rkeys = {k for k, _ in rust}
    mkeys = {k for k, _ in mirror}
    for k in sorted(rkeys - mkeys):
        failures.append(f"only rust enumerated {k}")
    for k in sorted(mkeys - rkeys):
        failures.append(f"only the mirror enumerated {k}")

    rmap, mmap = dict(rust), dict(mirror)
    disagreements = 0
    for k in sorted(rkeys & mkeys):
        if rmap[k] != mmap[k]:
            disagreements += 1
            failures.append(
                f"verdict disagreement on {k}: rust ok={rmap[k]}, "
                f"mirror ok={mmap[k]}")
    hazardous = sorted(k for k in rkeys & mkeys
                       if not rmap[k] and not mmap[k])
    for k in hazardous:
        failures.append(f"both sides report hazards on {k}")

    if failures:
        print(f"verify cross-check: FAIL ({len(failures)} problem(s))")
        for f in failures[:20]:
            print(f"  {f}")
        if len(failures) > 20:
            print(f"  ... {len(failures) - 20} more")
        sys.exit(1)

    print(f"verify cross-check: OK ({rust_n} (app, config, granularity) "
          f"verdicts agree between the Rust verifier and the Python "
          f"mirror; all clean)")


if __name__ == "__main__":
    main()
