#!/usr/bin/env python3
"""Render a hetstream trace JSON as a per-lane SVG/HTML Gantt chart.

The input is the canonical trace format `repro trace NAME --out t.json`
emits (and the golden trace under rust/tests/golden/): a `version: 1`
object whose `events` carry per-op `lane`, `stream`, `kind`, byte/FLOP
metadata and `start_ns`/`end_ns` intervals from the virtual clock.
The layout mirrors `rust/src/metrics/viz.rs` (`repro trace --svg`
renders the same chart without leaving Rust); this script exists for
post-hoc visualization of checked-in or archived traces.

Usage:
    python3 tools/trace_viz.py TRACE.json [-o OUT.svg] [--html]

With no -o the SVG (or HTML) goes to stdout.  Exit is non-zero on a
malformed trace, so CI can use an invocation as a format check.
"""

import argparse
import html
import json
import sys

CHART_W = 1000.0
MARGIN_L = 90.0
MARGIN_T = 40.0
ROW_H = 28.0
BAR_H = 18.0
AXIS_TICKS = 6

KIND_COLOR = {"h2d": "#4c78a8", "kex": "#f58518", "d2h": "#54a24a"}


def lane_rank(lane):
    """h2d first, then the kernel queues in numeric order (kex2 before
    kex10), then d2h, then anything else."""
    if lane == "h2d":
        return (0, 0, "")
    if lane == "d2h":
        return (2, 0, "")
    if lane.startswith("kex") and lane[3:].isdigit():
        return (1, int(lane[3:]), "")
    return (3, 0, lane)


def trace_svg(events):
    lanes = []
    for e in events:
        if e["lane"] not in lanes:
            lanes.append(e["lane"])
    lanes.sort(key=lane_rank)

    t0 = min((e["start_ns"] for e in events), default=0)
    t1 = max((e["end_ns"] for e in events), default=0)
    span = max(t1 - t0, 1)
    height = MARGIN_T + ROW_H * max(len(lanes), 1) + 30.0
    width = MARGIN_L + CHART_W + 20.0

    def x(ns):
        return MARGIN_L + (ns - t0) / span * CHART_W

    out = []
    out.append(
        f'<svg xmlns="http://www.w3.org/2000/svg" width="{width:.0f}" '
        f'height="{height:.0f}" viewBox="0 0 {width:.0f} {height:.0f}" '
        f'font-family="monospace" font-size="11">'
    )
    out.append(
        f'<text x="{MARGIN_L:g}" y="16" font-size="13">hetstream timeline '
        f"— {len(events)} events, {(t1 - t0) / 1e6:.3f} ms</text>"
    )
    if not events:
        out.append('<text x="90" y="60">(no events in trace)</text>')
        out.append("</svg>")
        return "\n".join(out) + "\n"

    grid_bottom = MARGIN_T + ROW_H * len(lanes)
    for k in range(AXIS_TICKS + 1):
        ns = t0 + (t1 - t0) * k // AXIS_TICKS
        gx = x(ns)
        if t1 - t0 < 10_000_000:
            label = f"{(ns - t0) / 1e3:.1f}µs"
        else:
            label = f"{(ns - t0) / 1e6:.2f}ms"
        out.append(
            f'<line x1="{gx:.1f}" y1="{MARGIN_T:g}" x2="{gx:.1f}" '
            f'y2="{grid_bottom:g}" stroke="#ddd"/>'
        )
        out.append(
            f'<text x="{gx:.1f}" y="{grid_bottom + 14.0:.1f}" '
            f'text-anchor="middle" fill="#555">{label}</text>'
        )

    for row, lane in enumerate(lanes):
        y = MARGIN_T + ROW_H * row
        out.append(
            f'<text x="{MARGIN_L - 8.0:.1f}" y="{y + BAR_H - 4.0:.1f}" '
            f'text-anchor="end" fill="#333">{html.escape(lane)}</text>'
        )
        for e in events:
            if e["lane"] != lane:
                continue
            x0, x1 = x(e["start_ns"]), x(e["end_ns"])
            w = max(x1 - x0, 0.5)
            bits = [f"seq {e['seq']} {e['kind']} stream {e['stream']}"]
            if e.get("label"):
                bits.append(e["label"])
            if e.get("bytes"):
                bits.append(f"{e['bytes']} B")
            if e.get("flops"):
                bits.append(f"{e['flops']} flop")
            bits.append(f"[{e['start_ns']} .. {e['end_ns']}] ns")
            tip = html.escape(" ".join(bits))
            color = KIND_COLOR.get(e["kind"], "#888")
            out.append(
                f'<rect x="{x0:.2f}" y="{y:.1f}" width="{w:.2f}" '
                f'height="{BAR_H:g}" fill="{color}" stroke="#333" '
                f'stroke-width="0.4" opacity="0.9"><title>{tip}</title></rect>'
            )
    out.append("</svg>")
    return "\n".join(out) + "\n"


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", help="trace JSON from `repro trace --out`")
    ap.add_argument("-o", "--out", help="output path (default: stdout)")
    ap.add_argument(
        "--html", action="store_true", help="wrap the SVG in a standalone HTML page"
    )
    args = ap.parse_args()

    with open(args.trace) as f:
        doc = json.load(f)
    if doc.get("version") != 1 or "events" not in doc:
        sys.exit(f"{args.trace}: not a hetstream trace (want version 1 + events)")
    events = doc["events"]
    for i, e in enumerate(events):
        for key in ("seq", "kind", "lane", "stream", "start_ns", "end_ns"):
            if key not in e:
                sys.exit(f"{args.trace}: event {i} missing `{key}`")
        if e["end_ns"] < e["start_ns"]:
            sys.exit(f"{args.trace}: event {i} ends before it starts")

    body = trace_svg(events)
    if args.html:
        body = (
            "<!doctype html><html><head><meta charset='utf-8'>"
            "<title>hetstream timeline</title></head><body>\n"
            + body
            + "</body></html>\n"
        )
    if args.out:
        with open(args.out, "w") as f:
            f.write(body)
        print(f"wrote {len(events)} events to {args.out}", file=sys.stderr)
    else:
        sys.stdout.write(body)


if __name__ == "__main__":
    main()
