#!/usr/bin/env python3
"""Validate BENCH_*.json load-harness artifacts (DESIGN.md §Bench).

``repro bench --json PATH`` emits a versioned per-second time series
(schema tag ``hetstream-bench-v3``); this checker is the offline half
of the contract: any bench artifact, from any commit, must carry the
expected shape so runs stay comparable across PRs.  v2 added
``config.backend`` (``sim`` | ``native``) — native latencies are real
host execution, so comparisons must never mix backends.  v3 added the
adaptive runtime: ``config.adaptive`` / ``config.max_lanes``, a
``totals.adaptive`` counter block (batching, lane elasticity, wakeup
switches), and per-tick ``mode`` (``park`` | ``spin``) / ``lanes`` /
``batches`` so mode flips and fleet growth are visible in the series.

Usage:
    python3 tools/bench_schema.py BENCH_*.json   # validate artifacts
    python3 tools/bench_schema.py --selftest     # validator self-check

Exits non-zero on the first malformed file (or a broken validator).
"""

from __future__ import annotations

import json
import sys

SCHEMA = "hetstream-bench-v3"

# (key, type) for each required section.  ``float`` accepts ints and
# None — the emitter writes ``null`` for NaN statistics (e.g. the p99
# of a tick that completed nothing).
CONFIG_KEYS = [
    ("tenants", int),
    ("rate", float),
    ("secs", float),
    ("open_loop", bool),
    ("lanes", int),
    ("adaptive", bool),
    ("max_lanes", int),
    ("profile", str),
    ("time_mode", str),
    ("backend", str),
]
TOTALS_KEYS = [
    ("completed", int),
    ("rejected", int),
    ("errors", int),
    ("duration_s", float),
    ("throughput_rps", float),
    ("queue_wait_avg_ms", float),
    ("modeled_total_ms", float),
]
LATENCY_KEYS = [("avg", float), ("p50", float), ("p99", float)]
CACHE_KEYS = [("hits", int), ("misses", int)]
ADAPTIVE_KEYS = [
    ("batches", int),
    ("batched_jobs", int),
    ("grows", int),
    ("retires", int),
    ("wakeup_switches", int),
    ("peak_lanes", int),
]
TENANT_KEYS = [
    ("tenant", str),
    ("completed", int),
    ("shed", int),
    ("errors", int),
    ("p99_ms", float),
]
TICK_KEYS = [
    ("t_s", int),
    ("completed", int),
    ("rejected", int),
    ("errors", int),
    ("throughput_rps", float),
    ("lat_avg_ms", float),
    ("lat_p50_ms", float),
    ("lat_p99_ms", float),
    ("queue_avg_ms", float),
    ("mode", str),
    ("lanes", int),
    ("batches", int),
]


def _check_fields(obj, keys, where):
    errors = []
    if not isinstance(obj, dict):
        return [f"{where}: expected an object, got {type(obj).__name__}"]
    for key, ty in keys:
        if key not in obj:
            errors.append(f"{where}: missing key `{key}`")
            continue
        v = obj[key]
        if ty is float:
            # Numeric statistic: ints, floats, or null (NaN placeholder).
            if v is not None and not isinstance(v, (int, float)):
                errors.append(f"{where}.{key}: expected number or null, got {v!r}")
        elif ty is int:
            # bool is an int subclass in Python; counts must be true ints.
            if not isinstance(v, int) or isinstance(v, bool) or v < 0:
                errors.append(f"{where}.{key}: expected non-negative integer, got {v!r}")
        elif not isinstance(v, ty):
            errors.append(f"{where}.{key}: expected {ty.__name__}, got {v!r}")
    return errors


def validate(doc) -> list[str]:
    """All schema violations in a parsed bench document (empty = valid)."""
    if not isinstance(doc, dict):
        return [f"top level: expected an object, got {type(doc).__name__}"]
    errors = []
    if doc.get("schema") != SCHEMA:
        errors.append(f"schema: expected `{SCHEMA}`, got {doc.get('schema')!r}")
    errors += _check_fields(doc.get("config"), CONFIG_KEYS, "config")
    totals = doc.get("totals")
    errors += _check_fields(totals, TOTALS_KEYS, "totals")
    if isinstance(totals, dict):
        errors += _check_fields(totals.get("latency_ms"), LATENCY_KEYS, "totals.latency_ms")
        errors += _check_fields(totals.get("cache"), CACHE_KEYS, "totals.cache")
        errors += _check_fields(totals.get("adaptive"), ADAPTIVE_KEYS, "totals.adaptive")

    tenants = doc.get("per_tenant")
    if not isinstance(tenants, list):
        errors.append("per_tenant: expected an array")
        tenants = []
    for i, t in enumerate(tenants):
        errors += _check_fields(t, TENANT_KEYS, f"per_tenant[{i}]")

    ticks = doc.get("ticks")
    if not isinstance(ticks, list) or not ticks:
        errors.append("ticks: expected a non-empty array (the per-second series)")
        ticks = []
    for i, t in enumerate(ticks):
        errors += _check_fields(t, TICK_KEYS, f"ticks[{i}]")
        if isinstance(t, dict) and t.get("t_s") != i:
            errors.append(f"ticks[{i}].t_s: series must be contiguous from 0, got {t.get('t_s')!r}")
        if isinstance(t, dict) and t.get("mode") not in ("park", "spin"):
            errors.append(f"ticks[{i}].mode: expected `park` or `spin`, got {t.get('mode')!r}")

    # Cross-section consistency: the series and the per-tenant rows
    # must partition the totals.
    if not errors:
        for key in ("completed", "rejected", "errors"):
            tick_sum = sum(t[key] for t in ticks)
            if tick_sum != totals[key]:
                errors.append(f"ticks.{key} sums to {tick_sum}, totals say {totals[key]}")
        tenant_done = sum(t["completed"] for t in tenants)
        if tenants and tenant_done != totals["completed"]:
            errors.append(
                f"per_tenant.completed sums to {tenant_done}, totals say {totals['completed']}"
            )
    return errors


def _sample_doc():
    return {
        "schema": SCHEMA,
        "config": {
            "tenants": 1,
            "rate": 5.0,
            "secs": 1.0,
            "open_loop": False,
            "lanes": 2,
            "adaptive": True,
            "max_lanes": 8,
            "profile": "mic31sp-sim",
            "time_mode": "virtual",
            "backend": "sim",
        },
        "totals": {
            "completed": 5,
            "rejected": 1,
            "errors": 0,
            "duration_s": 1.2,
            "throughput_rps": 4.17,
            "latency_ms": {"avg": 3.0, "p50": 2.5, "p99": 6.0},
            "queue_wait_avg_ms": 0.4,
            "modeled_total_ms": 120.0,
            "cache": {"hits": 4, "misses": 1},
            "adaptive": {
                "batches": 2,
                "batched_jobs": 5,
                "grows": 1,
                "retires": 1,
                "wakeup_switches": 2,
                "peak_lanes": 3,
            },
        },
        "per_tenant": [
            {"tenant": "tenant-0", "completed": 5, "shed": 1, "errors": 0, "p99_ms": 6.0},
        ],
        "ticks": [
            {
                "t_s": 0,
                "completed": 4,
                "rejected": 1,
                "errors": 0,
                "throughput_rps": 4.0,
                "lat_avg_ms": 3.0,
                "lat_p50_ms": 2.5,
                "lat_p99_ms": 6.0,
                "queue_avg_ms": 0.4,
                "mode": "spin",
                "lanes": 3,
                "batches": 2,
            },
            {
                "t_s": 1,
                "completed": 1,
                "rejected": 0,
                "errors": 0,
                "throughput_rps": 1.0,
                "lat_avg_ms": None,
                "lat_p50_ms": None,
                "lat_p99_ms": None,
                "queue_avg_ms": None,
                "mode": "park",
                "lanes": 2,
                "batches": 0,
            },
        ],
    }


def selftest() -> int:
    """The validator must accept a known-good doc and reject mutations."""
    good = _sample_doc()
    errs = validate(good)
    assert not errs, f"sample document must validate: {errs}"

    def mutated(**changes):
        doc = json.loads(json.dumps(good))
        for path, value in changes.items():
            cursor = doc
            *parents, leaf = path.split(".")
            for p in parents:
                cursor = cursor[int(p)] if p.isdigit() else cursor[p]
            if value is ...:
                del cursor[leaf]
            else:
                cursor[leaf] = value
        return doc

    bad = [
        ("wrong schema tag", mutated(schema="hetstream-bench-v0")),
        ("stale v1 schema tag", mutated(schema="hetstream-bench-v1")),
        ("stale v2 schema tag", mutated(schema="hetstream-bench-v2")),
        ("missing backend", mutated(**{"config.backend": ...})),
        ("missing adaptive flag", mutated(**{"config.adaptive": ...})),
        ("missing tick mode", mutated(**{"ticks.0.mode": ...})),
        ("unknown tick mode", mutated(**{"ticks.0.mode": "nap"})),
        ("missing tick lane series", mutated(**{"ticks.1.lanes": ...})),
        ("missing adaptive totals", mutated(**{"totals.adaptive": ...})),
        ("missing totals key", mutated(**{"totals.completed": ...})),
        ("negative count", mutated(**{"totals.rejected": -1})),
        ("string where number", mutated(**{"totals.latency_ms.p99": "fast"})),
        ("non-contiguous ticks", mutated(**{"ticks.1.t_s": 7})),
        ("tick sum mismatch", mutated(**{"ticks.0.completed": 17})),
        ("empty series", mutated(ticks=[])),
        ("tenant sum mismatch", mutated(**{"per_tenant.0.completed": 2})),
    ]
    for label, doc in bad:
        assert validate(doc), f"validator must reject: {label}"
    print(f"bench_schema selftest OK ({len(bad)} rejections)")
    return 0


def main(argv) -> int:
    if not argv or argv == ["--selftest"]:
        if argv:
            return selftest()
        print(__doc__)
        return 2
    status = 0
    for path in argv:
        try:
            with open(path, encoding="utf-8") as fh:
                doc = json.load(fh)
        except (OSError, json.JSONDecodeError) as e:
            print(f"{path}: unreadable bench JSON: {e}", file=sys.stderr)
            return 1
        errs = validate(doc)
        if errs:
            for e in errs:
                print(f"{path}: {e}", file=sys.stderr)
            status = 1
        else:
            ticks = len(doc["ticks"])
            done = doc["totals"]["completed"]
            print(f"{path}: OK ({ticks} tick(s), {done} completed)")
    return status


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
