"""L1 correctness: every Pallas kernel vs the pure-numpy oracle.

Hypothesis sweeps shapes and values within each kernel's supported chunk
envelope; shapes are drawn from small power-of-two sets so jit caching
keeps the suite fast.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import (
    blackscholes,
    burner,
    convsep,
    fwt,
    histogram,
    lavamd,
    matmul,
    nn,
    nw,
    reduction,
    ref,
    scan,
    stencil,
    transpose,
    vecadd,
)

RNG = np.random.default_rng(1234)
FAST = settings(max_examples=8, deadline=None)


def normals(rng_seed, *shape):
    return np.random.default_rng(rng_seed).normal(size=shape).astype(np.float32)


# --- nn -------------------------------------------------------------------

@FAST
@given(st.integers(0, 2**31 - 1), st.sampled_from([64, 256, 1024]))
def test_nn_dist(seed, n):
    rec = normals(seed, n, 2)
    tgt = normals(seed + 1, 2)
    got = np.array(nn.nn_dist(rec, tgt))
    np.testing.assert_allclose(got, ref.nn_dist(rec, tgt), rtol=1e-5, atol=1e-5)


def test_nn_dist_zero_distance():
    rec = np.array([[1.0, 2.0], [3.0, 4.0]], np.float32)
    got = np.array(nn.nn_dist(rec, np.array([1.0, 2.0], np.float32)))
    assert got[0] == 0.0
    np.testing.assert_allclose(got[1], np.sqrt(8.0), rtol=1e-6)


# --- vector add -------------------------------------------------------------

@FAST
@given(st.integers(0, 2**31 - 1), st.sampled_from([16, 512, 4096]))
def test_vector_add(seed, n):
    a, b = normals(seed, n), normals(seed + 1, n)
    np.testing.assert_allclose(np.array(vecadd.vector_add(a, b)), ref.vector_add(a, b))


# --- transpose --------------------------------------------------------------

@FAST
@given(st.integers(0, 2**31 - 1), st.sampled_from([(128, 128), (128, 256), (64, 128)]))
def test_transpose(seed, shape):
    x = normals(seed, *shape)
    np.testing.assert_array_equal(np.array(transpose.transpose(x)), ref.transpose(x))


# --- matmul -----------------------------------------------------------------

@FAST
@given(st.integers(0, 2**31 - 1), st.sampled_from([(128, 64, 128), (128, 128, 256)]))
def test_matmul(seed, dims):
    m, k, n = dims
    a, b = normals(seed, m, k), normals(seed + 1, k, n)
    np.testing.assert_allclose(np.array(matmul.matmul(a, b)), ref.matmul(a, b), rtol=2e-4, atol=2e-4)


def test_matmul_identity():
    a = normals(7, 128, 128)
    eye = np.eye(128, dtype=np.float32)
    np.testing.assert_allclose(np.array(matmul.matmul(a, eye)), a, rtol=1e-6)


# --- prefix sum ---------------------------------------------------------------

@FAST
@given(st.integers(0, 2**31 - 1), st.sampled_from([16, 128, 2048]))
def test_prefix_sum(seed, n):
    x = normals(seed, n)
    y, tot = scan.prefix_sum(x)
    ry, rtot = ref.prefix_sum(x)
    np.testing.assert_allclose(np.array(y), ry, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.array(tot), rtot, rtol=1e-4, atol=1e-4)


def test_prefix_sum_total_is_last():
    x = normals(3, 256)
    y, tot = scan.prefix_sum(x)
    assert np.array(y)[-1] == np.array(tot)[0]


# --- histogram ----------------------------------------------------------------

@FAST
@given(st.integers(0, 2**31 - 1), st.sampled_from([64, 1024]))
def test_histogram(seed, n):
    x = np.random.default_rng(seed).integers(0, 256, n).astype(np.int32)
    got = np.array(histogram.histogram(x))
    np.testing.assert_array_equal(got, ref.histogram(x))
    assert got.sum() == n  # conservation of mass


def test_histogram_single_bin():
    x = np.full(100, 42, np.int32)
    got = np.array(histogram.histogram(x))
    assert got[42] == 100 and got.sum() == 100


# --- black-scholes --------------------------------------------------------------

@FAST
@given(st.integers(0, 2**31 - 1), st.sampled_from([64, 512]))
def test_black_scholes(seed, n):
    r = np.random.default_rng(seed)
    s = r.uniform(5.0, 30.0, n).astype(np.float32)
    k = r.uniform(1.0, 100.0, n).astype(np.float32)
    t = r.uniform(0.25, 10.0, n).astype(np.float32)
    call, put = blackscholes.black_scholes(s, k, t)
    rcall, rput = ref.black_scholes(s, k, t)
    np.testing.assert_allclose(np.array(call), rcall, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.array(put), rput, rtol=1e-3, atol=1e-3)


def test_black_scholes_put_call_parity():
    n = 256
    r = np.random.default_rng(9)
    s = r.uniform(5.0, 30.0, n).astype(np.float32)
    k = r.uniform(1.0, 100.0, n).astype(np.float32)
    t = r.uniform(0.25, 10.0, n).astype(np.float32)
    call, put = map(np.array, blackscholes.black_scholes(s, k, t))
    # C - P = S - K * exp(-rT)
    np.testing.assert_allclose(
        call - put, s - k * np.exp(-blackscholes.RISKFREE * t), rtol=1e-3, atol=1e-2
    )


# --- fwt -------------------------------------------------------------------------

@FAST
@given(st.integers(0, 2**31 - 1), st.sampled_from([16, 64, 256]))
def test_fwt(seed, n):
    x = normals(seed, n)
    np.testing.assert_allclose(np.array(fwt.fwt(x)), ref.fwt(x), rtol=1e-3, atol=1e-3)


def test_fwt_involution():
    # WHT is an involution up to scaling: fwt(fwt(x)) == n * x.
    x = normals(5, 64)
    twice = np.array(fwt.fwt(np.array(fwt.fwt(x))))
    np.testing.assert_allclose(twice, 64.0 * x, rtol=1e-3, atol=1e-3)


# --- conv separable ----------------------------------------------------------------

@FAST
@given(st.integers(0, 2**31 - 1), st.sampled_from([(32, 64), (16, 128)]))
def test_conv_sep(seed, shape):
    rows, cols = shape
    h = convsep.HALO
    img = normals(seed, rows + 2 * h, cols)
    kr, kc = normals(seed + 1, 2 * h + 1), normals(seed + 2, 2 * h + 1)
    got = np.array(convsep.conv_sep(img, kr, kc))
    np.testing.assert_allclose(got, ref.conv_sep(img, kr, kc), rtol=1e-3, atol=1e-3)


def test_conv_sep_delta_kernel():
    # Delta filters in both passes reproduce the interior band.
    h = convsep.HALO
    img = normals(11, 32 + 2 * h, 64)
    delta = np.zeros(2 * h + 1, np.float32)
    delta[h] = 1.0
    got = np.array(convsep.conv_sep(img, delta, delta))
    np.testing.assert_allclose(got, img[h:-h, :], rtol=1e-6)


# --- stencil -----------------------------------------------------------------------

@FAST
@given(st.integers(0, 2**31 - 1), st.sampled_from([(16, 64), (64, 128)]))
def test_stencil2d(seed, shape):
    rows, cols = shape
    x = normals(seed, rows + 2, cols)
    np.testing.assert_allclose(
        np.array(stencil.stencil2d(x)), ref.stencil2d(x), rtol=1e-4, atol=1e-4
    )


def test_stencil2d_constant_field():
    # Interior of a constant field: c0*v + 4*c1*v except at column borders.
    x = np.full((18, 32), 2.0, np.float32)
    got = np.array(stencil.stencil2d(x))
    interior = 2.0 * (stencil.C0 + 4 * stencil.C1)
    np.testing.assert_allclose(got[:, 1:-1], interior, rtol=1e-6)


# --- lavaMD ------------------------------------------------------------------------

@FAST
@given(st.integers(0, 2**31 - 1), st.sampled_from([(64, 16), (128, 32)]))
def test_lavamd(seed, cfg):
    n, h = cfg
    x = normals(seed, n + 2 * h)
    got = np.array(lavamd.lavamd_box(x, n))
    np.testing.assert_allclose(got, ref.lavamd(x, n), rtol=1e-3, atol=1e-3)


def test_lavamd_identical_particles():
    # All particles at the same point: each sees 2H neighbours at distance 0.
    n, h = 32, 8
    x = np.zeros(n + 2 * h, np.float32)
    got = np.array(lavamd.lavamd_box(x, n))
    np.testing.assert_allclose(got, 2 * h, rtol=1e-5)


# --- nw ---------------------------------------------------------------------------

@FAST
@given(st.integers(0, 2**31 - 1), st.sampled_from([4, 8, 16]))
def test_nw_tile(seed, t):
    r = np.random.default_rng(seed)
    north = r.integers(-50, 50, t).astype(np.int32)
    west = r.integers(-50, 50, t).astype(np.int32)
    corner = r.integers(-50, 50, 1).astype(np.int32)
    sub = r.integers(-5, 10, (t, t)).astype(np.int32)
    got = np.array(nw.nw_tile(north, west, corner, sub)[0])
    np.testing.assert_array_equal(got, ref.nw_tile(north, west, corner, sub))


def test_nw_tile_monotone_gap_row():
    # Zero substitution scores and huge penalties force pure diagonal walk.
    t = 8
    north = (-10 * np.arange(1, t + 1)).astype(np.int32)
    west = (-10 * np.arange(1, t + 1)).astype(np.int32)
    corner = np.zeros(1, np.int32)
    sub = np.zeros((t, t), np.int32)
    got = np.array(nw.nw_tile(north, west, corner, sub)[0])
    # Diagonal elements accumulate only substitution scores (= 0).
    np.testing.assert_array_equal(np.diag(got), np.zeros(t, np.int64))


# --- reduction variants -------------------------------------------------------------

@FAST
@given(st.integers(0, 2**31 - 1), st.sampled_from([512, 4096]))
def test_reduction_v1(seed, n):
    x = normals(seed, n)
    np.testing.assert_allclose(
        np.array(reduction.reduction_v1(x)), ref.reduction_v1(x), rtol=1e-3, atol=1e-3
    )


@FAST
@given(st.integers(0, 2**31 - 1))
def test_reduction_v2(seed):
    x = normals(seed, 4096)
    got = np.array(reduction.reduction_v2(x))
    want = ref.reduction_v2(x, reduction.BLOCKS)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_reduction_variants_agree():
    x = normals(13, reduction.CHUNK)
    v1 = np.array(reduction.reduction_v1(x))[0]
    v2 = np.array(reduction.reduction_v2(x)).sum()
    np.testing.assert_allclose(v1, v2, rtol=1e-3)


# --- burner -------------------------------------------------------------------------

@pytest.mark.parametrize("iters", burner.ITER_VARIANTS)
def test_burner(iters):
    x = normals(17, 1024)
    np.testing.assert_allclose(
        np.array(burner.burner(x, iters)), ref.burner(x, iters), rtol=1e-4, atol=1e-5
    )


# --- cfft2d (L2 composition) ----------------------------------------------------------

@FAST
@given(st.integers(0, 2**31 - 1), st.sampled_from([8, 16, 32]))
def test_cfft2d(seed, t):
    tile = normals(seed, t, t)
    filt = normals(seed + 1, t, t)
    got = np.array(model.cfft2d_chunk(tile, filt)[0])
    np.testing.assert_allclose(got, ref.cfft2d(tile, filt), rtol=1e-2, atol=1e-2)


def test_cfft2d_delta_filter():
    # Convolving with a delta at the origin is the identity.
    t = 16
    tile = normals(21, t, t)
    filt = np.zeros((t, t), np.float32)
    filt[0, 0] = 1.0
    got = np.array(model.cfft2d_chunk(tile, filt)[0])
    np.testing.assert_allclose(got, tile, rtol=1e-3, atol=1e-3)


# --- dct8x8 -------------------------------------------------------------------------

from compile.kernels import dct8x8, dotproduct, hotspot


@FAST
@given(st.integers(0, 2**31 - 1), st.sampled_from([(16, 32), (64, 64)]))
def test_dct8x8(seed, shape):
    x = normals(seed, *shape)
    got = np.array(dct8x8.dct8x8(x))
    np.testing.assert_allclose(got, ref.dct8x8(x), rtol=1e-3, atol=1e-3)


def test_dct8x8_constant_block_energy():
    # A constant block concentrates all energy in the DC coefficient.
    x = np.full((8, 8), 3.0, np.float32)
    got = np.array(dct8x8.dct8x8(x))
    assert abs(got[0, 0] - 24.0) < 1e-3  # 8 * 3 * (1/sqrt(2))^2 * ... = 24
    assert np.abs(got).sum() - abs(got[0, 0]) < 1e-3


# --- dot product ---------------------------------------------------------------------

@FAST
@given(st.integers(0, 2**31 - 1), st.sampled_from([64, 4096]))
def test_dot_product(seed, n):
    a, b = normals(seed, n), normals(seed + 1, n)
    got = np.array(dotproduct.dot_product(a, b))
    np.testing.assert_allclose(got, ref.dot_product(a, b), rtol=1e-3, atol=1e-3)


def test_dot_product_orthogonal():
    a = np.array([1.0, 0.0, 1.0, 0.0], np.float32)
    b = np.array([0.0, 2.0, 0.0, 2.0], np.float32)
    assert np.array(dotproduct.dot_product(a, b))[0] == 0.0


# --- hotspot -------------------------------------------------------------------------

@FAST
@given(st.integers(0, 2**31 - 1), st.sampled_from([16, 64]))
def test_hotspot_step(seed, n):
    t = normals(seed, n, n)
    p = normals(seed + 1, n, n)
    got = np.array(hotspot.hotspot_step(t, p))
    np.testing.assert_allclose(got, ref.hotspot_step(t, p), rtol=1e-4, atol=1e-4)


def test_hotspot_boundary_preserved():
    t = normals(5, 32, 32)
    p = normals(6, 32, 32)
    got = np.array(hotspot.hotspot_step(t, p))
    np.testing.assert_array_equal(got[0, :], t[0, :])
    np.testing.assert_array_equal(got[:, -1], t[:, -1])


def test_hotspot_equilibrium_fixed_point():
    # Uniform temperature + zero power: laplacian = 0 -> fixed point.
    t = np.full((16, 16), 5.0, np.float32)
    p = np.zeros((16, 16), np.float32)
    got = np.array(hotspot.hotspot_step(t, p))
    np.testing.assert_array_equal(got, t)
