"""AOT pipeline checks: manifest integrity and HLO-text artifact health."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART_DIR, "manifest.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="run `make artifacts` first"
)


def _manifest():
    with open(MANIFEST) as f:
        return json.load(f)


def test_manifest_covers_all_specs():
    names = {a["name"] for a in _manifest()["artifacts"]}
    spec_names = {s[0] for s in aot._spec_list()}
    assert names == spec_names


def test_manifest_format_version():
    assert _manifest()["format"] == "hlo-text/v1"


def test_artifact_files_exist_and_parse():
    for a in _manifest()["artifacts"]:
        path = os.path.join(ART_DIR, a["file"])
        assert os.path.exists(path), a["file"]
        text = open(path).read()
        assert "ENTRY" in text and "HloModule" in text  # parseable HLO text


def test_manifest_shapes_match_eval_shape():
    specs = {s[0]: s for s in aot._spec_list()}
    for a in _manifest()["artifacts"]:
        _, fn, example_args, _ = specs[a["name"]]
        outs = jax.eval_shape(fn, *example_args)
        assert len(a["outputs"]) == len(outs)
        for rec, o in zip(a["outputs"], outs):
            assert tuple(rec["shape"]) == o.shape
        for rec, arg in zip(a["inputs"], example_args):
            assert tuple(rec["shape"]) == arg.shape


def test_flop_estimates_positive():
    for a in _manifest()["artifacts"]:
        assert a["flops_per_call"] > 0


def test_lowering_is_deterministic():
    """Re-lowering a spec yields identical HLO text (reproducible builds)."""
    name, fn, example_args, _ = aot._spec_list()[0]
    t1 = aot.to_hlo_text(jax.jit(fn).lower(*example_args))
    t2 = aot.to_hlo_text(jax.jit(fn).lower(*example_args))
    assert t1 == t2


def test_dtype_names_restricted():
    for a in _manifest()["artifacts"]:
        for io in a["inputs"] + a["outputs"]:
            assert io["dtype"] in ("f32", "i32")
