"""L2 correctness: chunked (streamed) compositions equal the whole-array
computation — the invariant that makes the L3 partitioners sound.

Each test mirrors what the Rust workload drivers do: cut the input the
way the matching partitioner would (independent chunks / halo bands /
wavefront tiles), run the chunk function per task, reassemble, and
compare against the unpartitioned oracle.
"""

import numpy as np

from compile import model
from compile.kernels import convsep, lavamd, nw, ref, scan

RNG = np.random.default_rng(42)


def test_nn_chunked_equals_full():
    n, chunks = 1024, 4
    rec = RNG.normal(size=(n, 2)).astype(np.float32)
    tgt = np.array([0.25, -0.5], np.float32)
    parts = [
        np.array(model.nn_chunk(rec[i::1][: n // chunks] if False else rec[i * (n // chunks):(i + 1) * (n // chunks)], tgt)[0])
        for i in range(chunks)
    ]
    np.testing.assert_allclose(np.concatenate(parts), ref.nn_dist(rec, tgt), rtol=1e-5, atol=1e-5)


def test_scan_chunked_with_host_carry():
    n, chunks = 2048, 8
    x = RNG.normal(size=n).astype(np.float32)
    outs, carry = [], np.float32(0.0)
    for i in range(chunks):
        part = x[i * (n // chunks):(i + 1) * (n // chunks)]
        y, tot = model.scan_chunk(part)
        outs.append(np.array(y) + carry)  # host-side carry propagation
        carry = carry + np.array(tot)[0]
    got = np.concatenate(outs)
    want, _ = ref.prefix_sum(x)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_histogram_chunked_merge():
    n, chunks = 4096, 4
    x = RNG.integers(0, 256, n).astype(np.int32)
    merged = np.zeros(256, np.int64)
    for i in range(chunks):
        part = x[i * (n // chunks):(i + 1) * (n // chunks)]
        merged += np.array(model.histogram_chunk(part)[0])
    np.testing.assert_array_equal(merged.astype(np.int32), ref.histogram(x))


def test_transpose_banded():
    rows, cols, bands = 256, 128, 4
    x = RNG.normal(size=(rows, cols)).astype(np.float32)
    rb = rows // bands
    strips = [np.array(model.transpose_chunk(x[i * rb:(i + 1) * rb, :])[0]) for i in range(bands)]
    got = np.concatenate(strips, axis=1)
    np.testing.assert_array_equal(got, ref.transpose(x))


def test_matmul_row_bands():
    m, k, n, bands = 256, 64, 128, 4
    a = RNG.normal(size=(m, k)).astype(np.float32)
    b = RNG.normal(size=(k, n)).astype(np.float32)
    mb = m // bands
    parts = [np.array(model.matmul_chunk(a[i * mb:(i + 1) * mb, :], b)[0]) for i in range(bands)]
    np.testing.assert_allclose(np.concatenate(parts), ref.matmul(a, b), rtol=2e-4, atol=2e-4)


def test_convsep_halo_bands():
    h = convsep.HALO
    rows, cols, bands = 128, 64, 4
    img = RNG.normal(size=(rows, cols)).astype(np.float32)
    kr = RNG.normal(size=2 * h + 1).astype(np.float32)
    kc = RNG.normal(size=2 * h + 1).astype(np.float32)
    # Oracle over the zero-padded full image.
    padded = np.pad(img, ((h, h), (0, 0)))
    want = ref.conv_sep(padded, kr, kc)
    rb = rows // bands
    parts = []
    for i in range(bands):
        lo, hi = i * rb, (i + 1) * rb
        band = padded[lo : hi + 2 * h, :]  # halo rows ship redundantly
        parts.append(np.array(model.convsep_chunk(band, kr, kc)[0]))
    np.testing.assert_allclose(np.concatenate(parts), want, rtol=1e-3, atol=1e-3)


def test_stencil_halo_bands():
    rows, cols, bands = 64, 128, 4
    x = RNG.normal(size=(rows, cols)).astype(np.float32)
    padded = np.pad(x, ((1, 1), (0, 0)))
    want = ref.stencil2d(padded)
    rb = rows // bands
    parts = []
    for i in range(bands):
        band = padded[i * rb : (i + 1) * rb + 2, :]
        parts.append(np.array(model.stencil_chunk(band)[0]))
    np.testing.assert_allclose(np.concatenate(parts), want, rtol=1e-4, atol=1e-4)


def test_lavamd_halo_chunks():
    n, chunks, h = 256, 4, 16
    x = RNG.normal(size=n).astype(np.float32)
    padded = np.pad(x, (h, h))
    want = ref.lavamd(padded, n)
    nc = n // chunks
    parts = []
    for i in range(chunks):
        win = padded[i * nc : i * nc + nc + 2 * h]
        parts.append(np.array(lavamd.lavamd_box(win, nc)))
    np.testing.assert_allclose(np.concatenate(parts), want, rtol=1e-3, atol=1e-3)


def test_nw_wavefront_tiles_equal_full_matrix():
    """Tiled wavefront NW == whole-matrix DP — the True Dependent invariant."""
    t, tiles = 8, 3  # 24x24 matrix of 8x8 tiles
    size = t * tiles
    penalty = nw.PENALTY
    sub = RNG.integers(-5, 10, (size, size)).astype(np.int32)
    want = ref.nw_full(sub, penalty)

    # Boundary rows per Rodinia: -penalty * (1-based index).
    full = np.zeros((size, size), np.int64)
    for d in range(2 * tiles - 1):  # diagonal-by-diagonal (paper Fig. 8)
        for bi in range(tiles):
            bj = d - bi
            if bj < 0 or bj >= tiles:
                continue
            r0, c0 = bi * t, bj * t
            north = (
                full[r0 - 1, c0 : c0 + t]
                if r0 > 0
                else -penalty * np.arange(c0 + 1, c0 + t + 1)
            ).astype(np.int32)
            west = (
                full[r0 : r0 + t, c0 - 1]
                if c0 > 0
                else -penalty * np.arange(r0 + 1, r0 + t + 1)
            ).astype(np.int32)
            if r0 > 0 and c0 > 0:
                corner = np.array([full[r0 - 1, c0 - 1]], np.int32)
            elif r0 > 0:
                corner = np.array([-penalty * r0], np.int32)
            elif c0 > 0:
                corner = np.array([-penalty * c0], np.int32)
            else:
                corner = np.zeros(1, np.int32)
            tile = np.array(
                model.nw_chunk(north, west, corner, sub[r0 : r0 + t, c0 : c0 + t])[0]
            )
            full[r0 : r0 + t, c0 : c0 + t] = tile
    np.testing.assert_array_equal(full.astype(np.int32), want)


def test_reduction_v1_chunked():
    n, chunks = 8192, 8
    x = RNG.normal(size=n).astype(np.float32)
    total = sum(float(np.array(model.reduction_v1_chunk(x[i * (n // chunks):(i + 1) * (n // chunks)])[0])[0]) for i in range(chunks))
    np.testing.assert_allclose(total, x.astype(np.float64).sum(), rtol=1e-3)


def test_cfft2d_tiles_independent():
    # Spectral conv per tile: each tile convolves independently (overlap-save
    # aprons are the L3 partitioner's job; here tiles are exact).
    t = 16
    tiles = [RNG.normal(size=(t, t)).astype(np.float32) for _ in range(3)]
    filt = RNG.normal(size=(t, t)).astype(np.float32)
    for tile in tiles:
        got = np.array(model.cfft2d_chunk(tile, filt)[0])
        np.testing.assert_allclose(got, ref.cfft2d(tile, filt), rtol=1e-2, atol=1e-2)
