"""AOT pipeline: lower every L2 chunk computation to an HLO-text artifact.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version the Rust ``xla`` crate binds) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly.  See /opt/xla-example/README.md.

Every artifact is recorded in ``artifacts/manifest.json`` with its input
and output shapes/dtypes plus a FLOP estimate, which the Rust
``runtime::ArtifactStore`` reads to type-check calls at load time.

Usage: ``cd python && python -m compile.aot --out ../artifacts``
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import (
    blackscholes,
    burner,
    cfft,
    convsep,
    dct8x8,
    dotproduct,
    fwt,
    hotspot,
    histogram,
    lavamd,
    matmul,
    nn,
    nw,
    reduction,
    scan,
    stencil,
    transpose,
    vecadd,
)


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _spec_list():
    """(name, fn, example_args, flops_per_call) for every AOT variant."""
    h2 = 2 * convsep.HALO + 1
    lv_n = lavamd.CHUNK + 2 * lavamd.HALO
    specs = [
        # Embarrassingly Independent
        ("nn_dist", model.nn_chunk, (f32(nn.CHUNK, 2), f32(2)), 6 * nn.CHUNK),
        ("vector_add", model.vecadd_chunk, (f32(vecadd.CHUNK), f32(vecadd.CHUNK)), vecadd.CHUNK),
        (
            "transpose",
            model.transpose_chunk,
            (f32(transpose.ROWS, transpose.COLS),),
            transpose.ROWS * transpose.COLS,
        ),
        (
            "matmul",
            model.matmul_chunk,
            (f32(matmul.M, matmul.K), f32(matmul.K, matmul.N)),
            2 * matmul.M * matmul.K * matmul.N,
        ),
        ("prefix_sum", model.scan_chunk, (f32(scan.CHUNK),), scan.CHUNK),
        ("histogram", model.histogram_chunk, (i32(histogram.CHUNK),), 2 * histogram.CHUNK),
        (
            "black_scholes",
            model.blackscholes_chunk,
            (f32(blackscholes.CHUNK),) * 3,
            60 * blackscholes.CHUNK,
        ),
        (
            "dct8x8",
            model.dct8x8_chunk,
            (f32(dct8x8.ROWS, dct8x8.COLS), f32(8, 8)),
            32 * dct8x8.ROWS * dct8x8.COLS,
        ),
        (
            "dot_product",
            model.dotproduct_chunk,
            (f32(dotproduct.CHUNK), f32(dotproduct.CHUNK)),
            2 * dotproduct.CHUNK,
        ),
        # Iterative control
        (
            "hotspot_step",
            model.hotspot_chunk,
            (f32(hotspot.N, hotspot.N), f32(hotspot.N, hotspot.N)),
            8 * hotspot.N * hotspot.N,
        ),
        # False Dependent
        ("fwt", model.fwt_chunk, (f32(fwt.CHUNK),), 2 * fwt.CHUNK * 12),
        (
            "conv_sep",
            model.convsep_chunk,
            (f32(convsep.ROWS + 2 * convsep.HALO, convsep.COLS), f32(h2), f32(h2)),
            4 * h2 * convsep.ROWS * convsep.COLS,
        ),
        (
            "stencil2d",
            model.stencil_chunk,
            (f32(stencil.ROWS + 2, stencil.COLS),),
            6 * stencil.ROWS * stencil.COLS,
        ),
        ("lavamd_box", model.lavamd_chunk, (f32(lv_n),), 5 * (2 * lavamd.HALO + 1) * lavamd.CHUNK),
        (
            "cfft2d",
            model.cfft2d_chunk,
            (f32(cfft.TILE, cfft.TILE), f32(cfft.TILE, cfft.TILE)),
            int(30 * cfft.TILE * cfft.TILE * 7),  # ~3 FFTs + pointwise
        ),
        # True Dependent
        (
            "nw_tile",
            model.nw_chunk,
            (i32(nw.TILE), i32(nw.TILE), i32(1), i32(nw.TILE, nw.TILE)),
            5 * nw.TILE * nw.TILE,
        ),
        # Fig. 3 variants
        ("reduction_v1", model.reduction_v1_chunk, (f32(reduction.CHUNK),), reduction.CHUNK),
        ("reduction_v2", model.reduction_v2_chunk, (f32(reduction.CHUNK),), reduction.CHUNK),
    ]
    # Burner variants for descriptor-backed corpus entries.
    for iters in burner.ITER_VARIANTS:
        specs.append(
            (
                f"burner_{iters}",
                model.make_burner_chunk(iters),
                (f32(burner.CHUNK),),
                2 * burner.CHUNK * iters,
            )
        )
    return specs


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_name(dt) -> str:
    return {"float32": "f32", "int32": "i32"}[jnp.dtype(dt).name]


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="artifact output dir")
    parser.add_argument("--only", default=None, help="comma-separated artifact names")
    args = parser.parse_args()
    os.makedirs(args.out, exist_ok=True)

    only = set(args.only.split(",")) if args.only else None
    manifest = {"format": "hlo-text/v1", "artifacts": []}
    for name, fn, example_args, flops in _spec_list():
        if only and name not in only:
            continue
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *example_args)
        manifest["artifacts"].append(
            {
                "name": name,
                "file": fname,
                "inputs": [
                    {"shape": list(a.shape), "dtype": _dtype_name(a.dtype)}
                    for a in example_args
                ],
                "outputs": [
                    {"shape": list(o.shape), "dtype": _dtype_name(o.dtype)}
                    for o in outs
                ],
                "flops_per_call": int(flops),
            }
        )
        print(f"  lowered {name:16s} -> {fname} ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {len(manifest['artifacts'])} artifacts + manifest to {args.out}")


if __name__ == "__main__":
    main()
