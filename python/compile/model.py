"""L2 — chunk-level JAX computations, one per streamed benchmark.

Each function here is the *task body* the paper's streamed ports run per
stream: it consumes one chunk (plus halo where the category requires it)
and produces that chunk's output.  The compute hot-spot is an L1 Pallas
kernel (``kernels/``); anything XLA fuses well natively (e.g. FFTs) stays
at this layer.  ``aot.py`` lowers every function below to an HLO-text
artifact the Rust runtime executes — Python never runs at request time.
"""

import jax
import jax.numpy as jnp

from .kernels import (
    blackscholes,
    burner,
    cfft,
    convsep,
    dct8x8,
    dotproduct,
    fwt,
    hotspot,
    histogram,
    lavamd,
    matmul,
    nn,
    nw,
    reduction,
    scan,
    stencil,
    transpose,
    vecadd,
)


# --- Embarrassingly Independent -----------------------------------------

def nn_chunk(records, target):
    """Rodinia nn: distances of one record chunk to the target."""
    return (nn.nn_dist(records, target),)


def vecadd_chunk(a, b):
    """VectorAdd: c = a + b for one chunk."""
    return (vecadd.vector_add(a, b),)


def transpose_chunk(x):
    """Transpose: one row band -> transposed column strip."""
    return (transpose.transpose(x),)


def matmul_chunk(a, b):
    """MatrixMul/sgemm: one row band of A times (shared) B."""
    return (matmul.matmul(a, b),)


def scan_chunk(x):
    """PrefixSum: per-chunk inclusive scan + chunk total (host carries)."""
    return scan.prefix_sum(x)


def histogram_chunk(x):
    """Histogram: per-chunk 256-bin counts (host merges)."""
    return (histogram.histogram(x),)


def blackscholes_chunk(s, k, t):
    """BlackScholes: (call, put) prices for one option chunk."""
    return blackscholes.black_scholes(s, k, t)


def dct8x8_chunk(x, basis):
    """DCT8x8: blockwise 2D DCT of one row band (basis broadcast in)."""
    return (dct8x8.dct8x8(x, basis),)


def dotproduct_chunk(a, b):
    """DotProduct: one chunk's partial dot product (host reduces)."""
    return (dotproduct.dot_product(a, b),)


# --- Iterative (non-streamable control, Table 2) --------------------------

def hotspot_chunk(temp, power):
    """hotspot: one shape-preserving diffusion step (device ping-pong)."""
    return (hotspot.hotspot_step(temp, power),)


# --- False Dependent (redundant boundary/halo transfer) ------------------

def fwt_chunk(x):
    """FastWalshTransform: transform of one (boundary-padded) block."""
    return (fwt.fwt(x),)


def convsep_chunk(img_halo, krow, kcol):
    """ConvolutionSeparable: both passes over one halo-padded row band."""
    return (convsep.conv_sep(img_halo, krow, kcol),)


def stencil_chunk(x_halo):
    """Parboil stencil: one Jacobi step over a halo-padded row band."""
    return (stencil.stencil2d(x_halo),)


def lavamd_chunk(x_halo):
    """lavaMD: particle potentials for one box chunk plus halo window."""
    return (lavamd.lavamd_box(x_halo, lavamd.CHUNK),)


def cfft2d_chunk(tile, filt):
    """ConvolutionFFT2D: circular conv of one tile with the filter.

    FFT/IFFT run at this layer (XLA-native FFT op); the spectral pointwise
    multiply is the L1 Pallas kernel.
    """
    ft = jnp.fft.fft2(tile.astype(jnp.complex64))
    ff = jnp.fft.fft2(filt.astype(jnp.complex64))
    re, im = cfft.complex_pointwise_mul(
        jnp.real(ft), jnp.imag(ft), jnp.real(ff), jnp.imag(ff)
    )
    out = jnp.fft.ifft2(jax.lax.complex(re, im))
    return (jnp.real(out),)


# --- True Dependent (wavefront) ------------------------------------------

def nw_chunk(north, west, corner, sub):
    """Needleman-Wunsch: one DP tile given its north/west/corner edges.

    Returns (tile, south edge, east edge) — the edges are separate
    contiguous outputs so dependent tiles can read them as flat device
    regions.
    """
    return nw.nw_tile(north, west, corner, sub)


# --- Fig. 3 code variants & synthetic corpus backing ----------------------

def reduction_v1_chunk(x):
    """Reduction v1: full device-side sum (scalar D2H)."""
    return (reduction.reduction_v1(x),)


def reduction_v2_chunk(x):
    """Reduction v2: partial sums shipped to the host final pass."""
    return (reduction.reduction_v2(x),)


def make_burner_chunk(iters):
    """Burner variant: `iters` FMA sweeps over one block."""

    def burner_chunk(x):
        return (burner.burner(x, iters),)

    burner_chunk.__name__ = f"burner_{iters}_chunk"
    return burner_chunk
