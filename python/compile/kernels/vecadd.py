"""NVIDIA SDK ``VectorAdd`` — elementwise c = a + b.

Category: *Embarrassingly Independent*.  The simplest streamable code:
two H2D transfers feed one KEX, no inter-task data sharing.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Elements per chunk executable.
CHUNK = 65536


def _kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] + b_ref[...]


def vector_add(a, b):
    """a, b: f32[N] -> f32[N]."""
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct(a.shape, jnp.float32),
        interpret=True,
    )(a, b)
