"""NVIDIA SDK ``Reduction`` — the paper's Fig. 3 code-variant study.

Two variants with *different data-transfer requirements*:

- **v1** reduces the whole chunk to a scalar on the device (D2H = 4 bytes)
  — the variant that "performs the whole reduction work on the
  accelerator, thus significantly reducing the data-moving overheads".
- **v2** reduces each block to a partial sum and ships the partials back
  for a host-side final pass (D2H = NB * 4 bytes) — the variant with the
  larger D2H fraction.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Elements per chunk.
CHUNK = 65536
#: Partial sums emitted by v2.
BLOCKS = 256


def _kernel_v1(x_ref, o_ref):
    o_ref[...] = jnp.sum(x_ref[...])[None]


def _kernel_v2(x_ref, o_ref):
    n = x_ref.shape[0]
    o_ref[...] = jnp.sum(x_ref[...].reshape(BLOCKS, n // BLOCKS), axis=1)


def reduction_v1(x):
    """x: f32[N] -> f32[1] full device-side sum."""
    return pl.pallas_call(
        _kernel_v1,
        out_shape=jax.ShapeDtypeStruct((1,), jnp.float32),
        interpret=True,
    )(x)


def reduction_v2(x):
    """x: f32[N] -> f32[BLOCKS] partial sums (final pass on host)."""
    return pl.pallas_call(
        _kernel_v2,
        out_shape=jax.ShapeDtypeStruct((BLOCKS,), jnp.float32),
        interpret=True,
    )(x)
