"""NVIDIA SDK ``MatrixMul`` / Parboil ``sgemm`` — row-band matmul.

Category: *Embarrassingly Independent*: A is partitioned into row bands,
B is broadcast (a SYNC-style shared input — the paper notes codes can mix
categories); each task computes its band of C = A @ B.

Hardware adaptation: OpenCL work-group tiles in local memory become a
Pallas grid of MXU-shaped (128, 128) output tiles; each tile contracts the
full K in VMEM with ``jnp.dot(..., preferred_element_type=f32)`` which
maps to the MXU systolic array on real TPU hardware.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: AOT chunk variant: band M x K times K x N.
M = 128
K = 256
N = 256
TILE_N = 128


def _kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)


def matmul(a, b):
    """a: f32[M, K]; b: f32[K, N] -> f32[M, N]."""
    m, k = a.shape
    _, n = b.shape
    grid = (n // TILE_N,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, k), lambda j: (0, 0)),
            pl.BlockSpec((k, TILE_N), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((m, TILE_N), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)
