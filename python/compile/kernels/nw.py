"""Rodinia ``nw`` (Needleman–Wunsch) — anti-diagonal DP tile kernel.

Category: *True Dependent* (paper Fig. 8): cell (i,j) depends on its
north, west and northwest neighbours (RAW), so the score matrix is
computed tile-by-tile along anti-diagonals; tiles on the same diagonal
run concurrently in different streams (L3's Wavefront partitioner).

This kernel computes one T x T tile given the tile's north edge, west
edge, northwest corner and reference (substitution score) tile.

Hardware adaptation: the OpenCL port walks intra-tile diagonals with
work-item barriers.  On TPU we keep an extended (T+1)x(T+1) score buffer
in VMEM and run a ``fori_loop`` over the 2T-1 anti-diagonals; every
iteration computes candidate scores for the whole tile with three shifted
reads (vectorized on the VPU) and commits only the cells of the current
diagonal via an iota mask — their neighbours are final by induction.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Tile side of the AOT variant (the paper's blocked NW uses 16..64).
TILE = 32
#: Rodinia's default gap penalty.
PENALTY = 10


def _kernel(north_ref, west_ref, corner_ref, sub_ref, o_ref, south_ref, east_ref):
    t = sub_ref.shape[0]
    penalty = PENALTY

    # Extended score matrix E[(T+1),(T+1)]: row 0 = north edge, col 0 =
    # west edge, E[0,0] = northwest corner, interior = scores to fill.
    top = jnp.concatenate([corner_ref[...], north_ref[...]])[None, :]
    left = west_ref[...][:, None]
    interior = jnp.zeros((t, t), jnp.int32)
    e0 = jnp.concatenate([top, jnp.concatenate([left, interior], axis=1)], axis=0)

    ii = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
    sub = sub_ref[...]

    def step(d, e):
        nw = e[:-1, :-1]  # E[i, j]     -> neighbour of interior (i, j)
        n = e[:-1, 1:]    # E[i, j+1]
        w = e[1:, :-1]    # E[i+1, j]
        cand = jnp.maximum(nw + sub, jnp.maximum(n - penalty, w - penalty))
        mask = (ii + jj) == d
        new_interior = jnp.where(mask, cand, e[1:, 1:])
        return e.at[1:, 1:].set(new_interior)

    e = jax.lax.fori_loop(0, 2 * t - 1, step, e0)
    tile = e[1:, 1:]
    o_ref[...] = tile
    # Contiguous edge outputs so neighbour tiles can DMA-read them as
    # flat device regions (a 2D column slice is not contiguous).
    south_ref[...] = tile[-1, :]
    east_ref[...] = tile[:, -1]


def nw_tile(north, west, corner, sub):
    """One NW DP tile.

    north: i32[T] (scores of the row above), west: i32[T] (column left),
    corner: i32[1] (northwest score), sub: i32[T,T] (substitution scores)
    -> (tile i32[T,T], south edge i32[T], east edge i32[T]).
    """
    t = sub.shape[0]
    return pl.pallas_call(
        _kernel,
        out_shape=(
            jax.ShapeDtypeStruct((t, t), jnp.int32),
            jax.ShapeDtypeStruct((t,), jnp.int32),
            jax.ShapeDtypeStruct((t,), jnp.int32),
        ),
        interpret=True,
    )(north, west, corner, sub)
