"""Parboil ``stencil`` — 5-point Jacobi step on a row band.

Category: *False Dependent*: band ``b`` reads one row owned by each
neighbouring band (read-only within a step), so the streamed port ships
one halo row per side with every task (paper Fig. 7 pattern).

out[r, c] = c0 * x[r, c] + c1 * (x[r-1, c] + x[r+1, c] + x[r, c-1] + x[r, c+1])
with zero boundaries along the columns.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Band geometry of the AOT variant (plus 1 halo row each side).
ROWS = 128
COLS = 512
C0 = 0.5
C1 = 0.125


def _kernel(x_ref, o_ref):
    rows, cols = o_ref.shape
    x = x_ref[...]
    center = x[1:-1, :]
    north = x[:-2, :]
    south = x[2:, :]
    west = jnp.pad(center, ((0, 0), (1, 0)))[:, :cols]
    east = jnp.pad(center, ((0, 0), (0, 1)))[:, 1:]
    o_ref[...] = jnp.float32(C0) * center + jnp.float32(C1) * (north + south + west + east)


def stencil2d(x_halo):
    """x_halo: f32[R + 2, C] (band plus halo rows) -> f32[R, C]."""
    rows = x_halo.shape[0] - 2
    cols = x_halo.shape[1]
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=True,
    )(x_halo)
