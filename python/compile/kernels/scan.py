"""AMD SDK ``PrefixSum`` / ``ScanLargeArrays`` — per-chunk inclusive scan.

Category: *Embarrassingly Independent* with a host-side carry: each task
scans its chunk and emits the chunk total; the host (L3) prefix-sums the
totals and adds the carry to each chunk — the classic scan-then-propagate
decomposition the SDK's multi-pass kernel uses, with the tiny middle pass
on the host.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Elements per chunk.
CHUNK = 16384


def _kernel(x_ref, o_ref, tot_ref):
    # Hillis–Steele doubling scan: log2(N) shifted adds.  (jnp.cumsum
    # lowers to a width-N reduce-window here — O(N^2) on the CPU backend
    # — so the classic data-parallel scan is both the faithful SDK
    # structure from the SDK kernels and orders of magnitude faster.)
    n = x_ref.shape[0]
    y = x_ref[...]
    k = 1
    while k < n:
        shifted = jnp.pad(y, (k, 0))[:n]
        y = y + shifted
        k *= 2
    o_ref[...] = y
    tot_ref[...] = y[-1:]


def prefix_sum(x):
    """x: f32[N] -> (inclusive scan f32[N], chunk total f32[1])."""
    n = x.shape[0]
    return pl.pallas_call(
        _kernel,
        out_shape=(
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ),
        interpret=True,
    )(x)
