"""NVIDIA SDK ``Histogram`` (256-bin) — per-chunk histogram.

Category: *Embarrassingly Independent* with a host-side merge: each task
histograms its chunk; the host adds the per-chunk counts (the D2H payload
is 256 ints — tiny — which is why the paper's hg port streams well).

Hardware adaptation: OpenCL privatizes per-work-group histograms in local
memory and merges with atomics; atomics don't exist in the TPU vector
model, so the chunk's one-hot matrix is reduced on the VPU instead
(``sum(one_hot(x))`` — a (N, 256) i32 reduction entirely in VMEM).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Elements per chunk.
CHUNK = 16384
#: Number of bins (byte-valued input).
BINS = 256


#: Elements one-hot-expanded per accumulation step (§Perf: a full
#: (N, 256) one-hot materializes 16 MiB and ran 3.2x slower on the CPU
#: backend; batched accumulation also matches the VMEM-tile structure a
#: real TPU lowering would want).
BATCH = 2048


def _kernel(x_ref, o_ref):
    x = x_ref[...]
    n = x.shape[0]
    if n <= BATCH:
        bins = jax.lax.broadcasted_iota(jnp.int32, (n, BINS), 1)
        o_ref[...] = jnp.sum((x[:, None] == bins).astype(jnp.int32), axis=0)
        return
    bins = jax.lax.broadcasted_iota(jnp.int32, (BATCH, BINS), 1)

    def step(i, acc):
        xs = jax.lax.dynamic_slice(x, (i * BATCH,), (BATCH,))
        return acc + jnp.sum((xs[:, None] == bins).astype(jnp.int32), axis=0)

    o_ref[...] = jax.lax.fori_loop(0, n // BATCH, step, jnp.zeros((BINS,), jnp.int32))


def histogram(x):
    """x: i32[N] with values in [0, 256) -> i32[256] counts."""
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((BINS,), jnp.int32),
        interpret=True,
    )(x)
