"""NVIDIA SDK ``BlackScholes`` — pointwise European option pricing.

Category: *Embarrassingly Independent*: every option prices alone; three
input arrays (spot, strike, expiry) stream in, two result arrays (call,
put) stream out — the paper's archetype of an H2D-heavy pointwise code.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Options per chunk.
CHUNK = 16384
#: Riskless rate and volatility (SDK defaults).
RISKFREE = 0.02
VOLATILITY = 0.30


def _erf(x):
    # Abramowitz–Stegun 7.1.26 polynomial erf (|err| < 1.5e-7), written in
    # basic ops only: xla_extension 0.5.1's HLO text parser predates the
    # dedicated `erf` opcode that jax >= 0.5 lowers `lax.erf` to.
    sign = jnp.sign(x)
    ax = jnp.abs(x)
    t = 1.0 / (1.0 + jnp.float32(0.3275911) * ax)
    poly = (
        (((jnp.float32(1.061405429) * t - jnp.float32(1.453152027)) * t
          + jnp.float32(1.421413741)) * t - jnp.float32(0.284496736)) * t
        + jnp.float32(0.254829592)
    ) * t
    return sign * (1.0 - poly * jnp.exp(-ax * ax))


def _cnd(d):
    # Cumulative normal distribution via erf.
    return 0.5 * (1.0 + _erf(d / jnp.sqrt(2.0).astype(jnp.float32)))


def _kernel(s_ref, k_ref, t_ref, call_ref, put_ref):
    s, k, t = s_ref[...], k_ref[...], t_ref[...]
    r = jnp.float32(RISKFREE)
    v = jnp.float32(VOLATILITY)
    sqrt_t = jnp.sqrt(t)
    d1 = (jnp.log(s / k) + (r + 0.5 * v * v) * t) / (v * sqrt_t)
    d2 = d1 - v * sqrt_t
    exp_rt = jnp.exp(-r * t)
    call = s * _cnd(d1) - k * exp_rt * _cnd(d2)
    put = k * exp_rt * _cnd(-d2) - s * _cnd(-d1)
    call_ref[...] = call
    put_ref[...] = put


def black_scholes(s, k, t):
    """s, k, t: f32[N] -> (call f32[N], put f32[N])."""
    shape = jax.ShapeDtypeStruct(s.shape, jnp.float32)
    return pl.pallas_call(
        _kernel,
        out_shape=(shape, shape),
        interpret=True,
    )(s, k, t)
