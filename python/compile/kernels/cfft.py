"""NVIDIA SDK ``ConvolutionFFT2D`` — pointwise spectral multiply kernel.

Category: *False Dependent*: the streamed port cuts the image into tiles
with filter-sized aprons (read-only overlap) and convolves each tile by
FFT -> pointwise complex multiply -> IFFT (overlap-save).

Layer split: the FFTs live in the L2 jax model (``model.cfft2d_chunk``)
where XLA's native FFT op runs them fused; the compute hot-spot this
module owns is the pointwise complex multiply of the tile spectrum with
the (precomputed) filter spectrum.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Tile side of the AOT variant (padded tile, power of two).
TILE = 128


def _kernel(ar_ref, ai_ref, br_ref, bi_ref, or_ref, oi_ref):
    ar, ai = ar_ref[...], ai_ref[...]
    br, bi = br_ref[...], bi_ref[...]
    or_ref[...] = ar * br - ai * bi
    oi_ref[...] = ar * bi + ai * br


def complex_pointwise_mul(ar, ai, br, bi):
    """(ar + i*ai) * (br + i*bi), all f32[T, T] -> (re, im)."""
    shape = jax.ShapeDtypeStruct(ar.shape, jnp.float32)
    return pl.pallas_call(
        _kernel,
        out_shape=(shape, shape),
        interpret=True,
    )(ar, ai, br, bi)
