"""Pure-numpy correctness oracles for every L1 Pallas kernel.

These are deliberately written in plain numpy loops / vector ops, with no
JAX, so a bug in the Pallas kernels cannot be mirrored here.  The pytest
+ hypothesis suite sweeps shapes/values and asserts allclose.
"""

import numpy as np


def nn_dist(records, target):
    rec = np.asarray(records, np.float32)
    t = np.asarray(target, np.float32)
    return np.sqrt((rec[:, 0] - t[0]) ** 2 + (rec[:, 1] - t[1]) ** 2).astype(np.float32)


def fwt(x):
    x = np.asarray(x, np.float64).copy()
    n = x.shape[0]
    h = 1
    while h < n:
        for i in range(0, n, h * 2):
            for j in range(i, i + h):
                a, b = x[j], x[j + h]
                x[j], x[j + h] = a + b, a - b
        h *= 2
    return x.astype(np.float32)


def nw_tile(north, west, corner, sub, penalty=10):
    t = sub.shape[0]
    e = np.zeros((t + 1, t + 1), np.int64)
    e[0, 0] = corner[0]
    e[0, 1:] = north
    e[1:, 0] = west
    for i in range(1, t + 1):
        for j in range(1, t + 1):
            e[i, j] = max(
                e[i - 1, j - 1] + sub[i - 1, j - 1],
                e[i - 1, j] - penalty,
                e[i, j - 1] - penalty,
            )
    return e[1:, 1:].astype(np.int32)


def nw_full(seq_scores, penalty=10):
    """Whole-matrix NW oracle; seq_scores: i32[R, C] substitution scores.

    Boundary condition (Rodinia): first row/col are -penalty * index.
    Returns the full i32[R, C] score matrix for the interior.
    """
    r, c = seq_scores.shape
    e = np.zeros((r + 1, c + 1), np.int64)
    e[0, :] = -penalty * np.arange(c + 1)
    e[:, 0] = -penalty * np.arange(r + 1)
    for i in range(1, r + 1):
        for j in range(1, c + 1):
            e[i, j] = max(
                e[i - 1, j - 1] + seq_scores[i - 1, j - 1],
                e[i - 1, j] - penalty,
                e[i, j - 1] - penalty,
            )
    return e[1:, 1:].astype(np.int32)


def lavamd(x_halo, n):
    x = np.asarray(x_halo, np.float64)
    h = (x.shape[0] - n) // 2
    out = np.zeros(n, np.float64)
    for i in range(n):
        c = x[h + i]
        win = x[i : i + 2 * h + 1]
        out[i] = np.sum(1.0 / (1.0 + (c - win) ** 2)) - 1.0
    return out.astype(np.float32)


def conv_sep(img_halo, krow, kcol):
    img = np.asarray(img_halo, np.float64)
    kr = np.asarray(krow, np.float64)
    kc = np.asarray(kcol, np.float64)
    h = (len(kr) - 1) // 2
    rows = img.shape[0] - 2 * h
    cols = img.shape[1]
    mid = np.zeros((rows, cols))
    for k in range(2 * h + 1):
        mid += img[k : k + rows, :] * kc[k]
    padded = np.pad(mid, ((0, 0), (h, h)))
    out = np.zeros((rows, cols))
    for k in range(2 * h + 1):
        out += padded[:, k : k + cols] * kr[k]
    return out.astype(np.float32)


def complex_pointwise_mul(ar, ai, br, bi):
    a = np.asarray(ar, np.float32) + 1j * np.asarray(ai, np.float32)
    b = np.asarray(br, np.float32) + 1j * np.asarray(bi, np.float32)
    c = a * b
    return c.real.astype(np.float32), c.imag.astype(np.float32)


def cfft2d(tile, filt):
    """Circular 2D convolution of tile with filt via FFT (both [T, T])."""
    fa = np.fft.fft2(np.asarray(tile, np.float64))
    fb = np.fft.fft2(np.asarray(filt, np.float64))
    return np.real(np.fft.ifft2(fa * fb)).astype(np.float32)


def transpose(x):
    return np.ascontiguousarray(np.asarray(x, np.float32).T)


def prefix_sum(x):
    y = np.cumsum(np.asarray(x, np.float64)).astype(np.float32)
    return y, y[-1:]


def histogram(x, bins=256):
    return np.bincount(np.asarray(x, np.int64), minlength=bins).astype(np.int32)


def matmul(a, b):
    return (np.asarray(a, np.float64) @ np.asarray(b, np.float64)).astype(np.float32)


def vector_add(a, b):
    return (np.asarray(a, np.float32) + np.asarray(b, np.float32)).astype(np.float32)


def _cnd(d):
    from math import erf, sqrt

    return 0.5 * (1.0 + np.vectorize(erf)(d / sqrt(2.0)))


def black_scholes(s, k, t, r=0.02, v=0.30):
    s = np.asarray(s, np.float64)
    k = np.asarray(k, np.float64)
    t = np.asarray(t, np.float64)
    sqrt_t = np.sqrt(t)
    d1 = (np.log(s / k) + (r + 0.5 * v * v) * t) / (v * sqrt_t)
    d2 = d1 - v * sqrt_t
    exp_rt = np.exp(-r * t)
    call = s * _cnd(d1) - k * exp_rt * _cnd(d2)
    put = k * exp_rt * _cnd(-d2) - s * _cnd(-d1)
    return call.astype(np.float32), put.astype(np.float32)


def stencil2d(x_halo, c0=0.5, c1=0.125):
    x = np.asarray(x_halo, np.float64)
    rows = x.shape[0] - 2
    cols = x.shape[1]
    center = x[1:-1, :]
    north = x[:-2, :]
    south = x[2:, :]
    west = np.pad(center, ((0, 0), (1, 0)))[:, :cols]
    east = np.pad(center, ((0, 0), (0, 1)))[:, 1:]
    return (c0 * center + c1 * (north + south + west + east)).astype(np.float32)


def reduction_v1(x):
    return np.sum(np.asarray(x, np.float64)).astype(np.float32).reshape(1)


def reduction_v2(x, blocks=256):
    x = np.asarray(x, np.float64)
    return np.sum(x.reshape(blocks, -1), axis=1).astype(np.float32)


def burner(x, iters):
    v = np.asarray(x, np.float32).copy()
    for _ in range(iters):
        v = v * np.float32(1.000001) + np.float32(1e-7)
    return v


def dct8x8(x):
    from .dct8x8 import BASIS

    x = np.asarray(x, np.float64)
    c = BASIS.astype(np.float64)
    rows, cols = x.shape
    out = np.zeros_like(x)
    for bi in range(rows // 8):
        for bj in range(cols // 8):
            b = x[bi * 8:(bi + 1) * 8, bj * 8:(bj + 1) * 8]
            out[bi * 8:(bi + 1) * 8, bj * 8:(bj + 1) * 8] = c @ b @ c.T
    return out.astype(np.float32)


def dot_product(a, b):
    return np.array([np.dot(np.asarray(a, np.float64), np.asarray(b, np.float64))], np.float64).astype(np.float32)


def hotspot_step(temp, power, k=0.1):
    t = np.asarray(temp, np.float64)
    p = np.asarray(power, np.float64)
    out = t.copy()
    lap = t[:-2, 1:-1] + t[2:, 1:-1] + t[1:-1, :-2] + t[1:-1, 2:] - 4.0 * t[1:-1, 1:-1]
    out[1:-1, 1:-1] = t[1:-1, 1:-1] + k * (p[1:-1, 1:-1] + lap)
    return out.astype(np.float32)
