"""NVIDIA SDK ``DotProduct`` — per-chunk partial dot products.

Category: *Embarrassingly Independent* with a tiny host reduce: each
task computes its chunk's partial sum; D2H is 4 bytes per task, making
this the extreme H2D-dominated streamable code (two input arrays in,
one scalar out).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Elements per chunk.
CHUNK = 65536


def _kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.sum(a_ref[...] * b_ref[...])[None]


def dot_product(a, b):
    """a, b: f32[N] -> f32[1] partial dot product."""
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((1,), jnp.float32),
        interpret=True,
    )(a, b)
