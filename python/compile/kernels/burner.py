"""Calibrated synthetic kernel backing descriptor-only corpus entries.

The statistical survey (paper Fig. 1) covers 56 benchmarks; 16 have real
kernels in this repo, and the rest are *descriptor-backed*: their bytes /
FLOP profile (from Table 1 input configs) drives the same H2D -> KEX ->
D2H pipeline, with KEX realized by this kernel — ``iters`` fused
multiply-add sweeps over a VMEM-resident block.  Because the burner runs
through the identical engines and allocator, the stage-time *ratios* (R)
keep the shape the real benchmarks produce.

AOT emits one variant per iteration count in ``ITER_VARIANTS``; the L3
compute engine composes calls to approximate a descriptor's FLOP budget.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Elements per burner block (256 KiB of f32 — comfortably VMEM-sized).
CHUNK = 65536
#: AOT-emitted iteration-count variants (each ~2*CHUNK*iters flops).
ITER_VARIANTS = (8, 64, 512)


def _make_kernel(iters):
    def _kernel(x_ref, o_ref):
        def step(_, v):
            return v * jnp.float32(1.000001) + jnp.float32(1e-7)

        o_ref[...] = jax.lax.fori_loop(0, iters, step, x_ref[...])

    return _kernel


def burner(x, iters):
    """x: f32[N] -> f32[N] after ``iters`` FMA sweeps."""
    return pl.pallas_call(
        _make_kernel(iters),
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=True,
    )(x)
