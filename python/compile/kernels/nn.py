"""Rodinia ``nn`` (nearest neighbor) — per-chunk Euclidean distance.

Category: *Embarrassingly Independent* (paper Fig. 6).  The record set is
split into chunks; each task computes the distance of every record in its
chunk to the target (lat, lng).  The k-nearest selection happens on the
host (L3), exactly like Rodinia's host-side partial sort.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Records per chunk executable (one AOT variant).
CHUNK = 16384


def _kernel(rec_ref, tgt_ref, o_ref):
    lat = rec_ref[:, 0]
    lng = rec_ref[:, 1]
    d2 = (lat - tgt_ref[0]) ** 2 + (lng - tgt_ref[1]) ** 2
    o_ref[...] = jnp.sqrt(d2)


def nn_dist(records, target):
    """records: f32[N,2]; target: f32[2] -> f32[N] distances."""
    n = records.shape[0]
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(records, target)
