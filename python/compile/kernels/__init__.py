"""L1 Pallas kernels for hetstream.

Each module implements one benchmark's chunk-level compute hot-spot as a
Pallas kernel (lowered with ``interpret=True`` so the staged-out HLO is
plain XLA ops runnable on the CPU PJRT client — see DESIGN.md
§Hardware-Adaptation).  ``ref.py`` holds the pure-jnp/numpy oracles the
pytest/hypothesis suite checks against.
"""

from . import (  # noqa: F401
    blackscholes,
    burner,
    cfft,
    convsep,
    dct8x8,
    dotproduct,
    fwt,
    hotspot,
    histogram,
    lavamd,
    matmul,
    nn,
    nw,
    reduction,
    scan,
    stencil,
    transpose,
    vecadd,
)
