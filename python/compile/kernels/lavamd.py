"""Rodinia ``lavaMD`` — particle potential over a neighborhood window.

Category: *False Dependent*, and the paper's **negative case** (§5): each
output element depends on 2H = 222 neighbours while the task holds only
~250 elements, so the redundant halo transfer is as large as the task
itself and streaming does not pay off.

Simplified physics faithful to the dependency structure: particles on a
1D line, ``out[i] = sum_{|j-i| <= H} 1 / (1 + (x[i] - x[j])^2)`` — an
inverse-square-style pairwise potential with a hard cutoff window, which
is exactly the halo pattern the paper analyzes (H = 111 either side).

Hardware adaptation: the OpenCL kernel loops neighbour *boxes* with the
home box in local memory; here the chunk-plus-halo vector sits in VMEM
and a ``fori_loop`` over the 2H+1 window offsets accumulates with
dynamic-sliced shifted reads (each iteration is a full-width VPU op).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Particles per task — paper's task size is ~250.
CHUNK = 256
#: Halo radius — paper: one element depends on 111 before + 111 after.
HALO = 111


def _kernel(x_ref, o_ref):
    n = o_ref.shape[0]
    h = (x_ref.shape[0] - n) // 2
    x = x_ref[...]
    center = jax.lax.dynamic_slice(x, (h,), (n,))

    def step(k, acc):
        nbr = jax.lax.dynamic_slice(x, (k,), (n,))
        d2 = (center - nbr) ** 2
        return acc + 1.0 / (1.0 + d2)

    acc = jax.lax.fori_loop(0, 2 * h + 1, step, jnp.zeros((n,), jnp.float32))
    # Remove the self-interaction term (k == h gives d2 == 0 -> 1.0).
    o_ref[...] = acc - 1.0


def lavamd_box(x_halo, n=CHUNK):
    """x_halo: f32[N + 2H] (chunk plus halo) -> f32[N] potentials."""
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(x_halo)
