"""NVIDIA SDK ``ConvolutionSeparable`` — separable 2D convolution on a row band.

Category: *False Dependent*: the column pass of band ``b`` reads H rows
owned by bands ``b-1``/``b+1`` (read-only), so the streamed port
redundantly transfers H halo rows on each side (paper Fig. 7 applied to
rows).

The kernel runs both passes over one band: a column (vertical) pass that
consumes the halo, then a row (horizontal) pass with zero padding at the
image borders (bands keep full image width, so there is no horizontal
halo — the adaptation of the OpenCL tiling that DESIGN.md §Hardware-
Adaptation describes).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Band geometry of the AOT variant.
ROWS = 128
COLS = 256
#: Filter radius (length 2H+1).
HALO = 8


def _kernel(img_ref, krow_ref, kcol_ref, o_ref):
    rows, cols = o_ref.shape
    h = (img_ref.shape[0] - rows) // 2
    img = img_ref[...]

    # Column pass: out1[r, c] = sum_k img[r + k, c] * kcol[k]
    def col_step(k, acc):
        sl = jax.lax.dynamic_slice(img, (k, 0), (rows, cols))
        return acc + sl * kcol_ref[k]

    mid = jax.lax.fori_loop(0, 2 * h + 1, col_step, jnp.zeros((rows, cols), jnp.float32))

    # Row pass with zero padding: out[r, c] = sum_k mid[r, c + k - h] * krow[k]
    padded = jnp.pad(mid, ((0, 0), (h, h)))

    def row_step(k, acc):
        sl = jax.lax.dynamic_slice(padded, (0, k), (rows, cols))
        return acc + sl * krow_ref[k]

    o_ref[...] = jax.lax.fori_loop(0, 2 * h + 1, row_step, jnp.zeros((rows, cols), jnp.float32))


def conv_sep(img_halo, krow, kcol):
    """img_halo: f32[R + 2H, C]; krow, kcol: f32[2H+1] -> f32[R, C]."""
    rows = img_halo.shape[0] - (krow.shape[0] - 1)
    cols = img_halo.shape[1]
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=True,
    )(img_halo, krow, kcol)
