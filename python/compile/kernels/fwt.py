"""NVIDIA/AMD SDK ``FastWalshTransform`` — radix-2 Walsh–Hadamard butterfly.

Category: *False Dependent* (paper Fig. 7): tasks share read-only (RAR)
input neighborhoods.  The paper streams FWT by cutting the signal into
blocks and redundantly transferring the boundary elements each block's
butterflies touch; a block of size B then transforms independently (the
first log2(B) stages of the full transform — the Rodinia/SDK streamed
port's per-task kernel).

Hardware adaptation: the OpenCL version stages each butterfly through
local memory with a barrier between stages; here the whole block lives in
VMEM, and the ``log2(B)`` stages are a statically unrolled sequence of
reshape + (a+b, a-b) vector ops — no barriers needed.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Elements per task block (one AOT variant).
CHUNK = 4096


def _kernel(x_ref, o_ref):
    n = x_ref.shape[0]
    x = x_ref[...]
    h = 1
    while h < n:
        y = x.reshape(n // (2 * h), 2, h)
        a = y[:, 0, :]
        b = y[:, 1, :]
        x = jnp.stack([a + b, a - b], axis=1).reshape(n)
        h *= 2
    o_ref[...] = x


def fwt(x):
    """x: f32[N] (N a power of two) -> Walsh–Hadamard transform of x."""
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=True,
    )(x)
