"""Rodinia ``hotspot`` — one time step of the thermal grid, shape-
preserving for device-side ping-pong iteration.

Category: *Iterative* (non-streamable, Table 2): the grid uploads once
and the kernel re-runs on resident data, so there is nothing for a
second stream to overlap after the first step — the workload driver
demonstrates exactly that (see `workloads/hotspot.rs`).

temp' = temp + k * (power + neighbor_laplacian(temp)); the padded
boundary rows/cols are copied through unchanged so output shape ==
input shape and step t+1 can consume step t's output in place.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Grid side of the AOT variant (padded; interior is (N-2)^2).
N = 128
K_THERMAL = 0.1


def _kernel(t_ref, p_ref, o_ref):
    t = t_ref[...]
    p = p_ref[...]
    lap = (
        t[:-2, 1:-1] + t[2:, 1:-1] + t[1:-1, :-2] + t[1:-1, 2:] - 4.0 * t[1:-1, 1:-1]
    )
    interior = t[1:-1, 1:-1] + jnp.float32(K_THERMAL) * (p[1:-1, 1:-1] + lap)
    o_ref[...] = t.at[1:-1, 1:-1].set(interior)


def hotspot_step(temp, power):
    """temp, power: f32[N, N] -> f32[N, N] after one diffusion step."""
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct(temp.shape, jnp.float32),
        interpret=True,
    )(temp, power)
