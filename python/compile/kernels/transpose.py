"""NVIDIA SDK ``Transpose`` — tiled matrix transpose of a row band.

Category: *Embarrassingly Independent*.  The matrix is partitioned into
row bands; each task reads a full-width band f32[RB, C] and writes the
transposed band f32[C, RB] (the host assembles the column strips).

Hardware adaptation: OpenCL uses a local-memory tile to get coalesced
global writes; on TPU the whole band sits in VMEM and the relayout is a
single vector shuffle, so the kernel is one transposed copy per grid tile.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Rows per band (chunk) and band width of the AOT variant.
ROWS = 128
COLS = 1024
TILE = 128  # grid tile along the columns


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...].T


def transpose(x):
    """x: f32[R, C] -> f32[C, R] (R rows = one band)."""
    r, c = x.shape
    grid = (c // TILE,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((r, TILE), lambda j: (0, j))],
        out_specs=pl.BlockSpec((TILE, r), lambda j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((c, r), jnp.float32),
        interpret=True,
    )(x)
