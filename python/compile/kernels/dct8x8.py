"""NVIDIA SDK ``DCT8x8`` — 8x8 blockwise 2D discrete cosine transform.

Category: *Embarrassingly Independent*: every 8x8 pixel block transforms
alone (JPEG-style), so the image streams in row bands of blocks.

Hardware adaptation: the OpenCL kernel assigns one 8x8 block per
work-group; here a whole band sits in VMEM reshaped to a batch of 8x8
blocks, and the two 1D DCT passes are batched (N, 8) x (8, 8) matmuls
against the DCT basis — MXU-friendly instead of per-thread butterflies.

The basis rides in as an artifact *input* rather than an embedded
constant, and the passes use plain 2D `jnp.dot`s: xla_extension 0.5.1's
HLO-text round-trip silently mis-executes the einsum/array-constant
formulation this kernel originally used (output all-zeros) — see
DESIGN.md §Hardware-Adaptation notes.
"""

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

#: Band geometry of the AOT variant: 8 block-rows of a 512-wide image.
ROWS = 64
COLS = 512


def _dct_basis():
    # Orthonormal DCT-II basis C[k, n] = s(k)/2 * cos(pi (2n+1) k / 16).
    k = np.arange(8)[:, None]
    n = np.arange(8)[None, :]
    c = np.cos(np.pi * (2 * n + 1) * k / 16.0)
    c[0, :] *= 1.0 / np.sqrt(2.0)
    return (c * 0.5).astype(np.float32)


BASIS = _dct_basis()


def _kernel(x_ref, c_ref, o_ref):
    rows, cols = x_ref.shape
    c = c_ref[...]
    nb_i, nb_j = rows // 8, cols // 8
    # (bi, 8, bj, 8) -> (blocks, 8, 8) batch.
    blocks = x_ref[...].reshape(nb_i, 8, nb_j, 8).transpose(0, 2, 1, 3)
    # Row pass: every block row times C^T.
    t1 = jnp.dot(blocks.reshape(-1, 8), c.T)
    # Column pass: transpose within blocks, multiply again.
    t1 = t1.reshape(-1, 8, 8).transpose(0, 2, 1)
    t2 = jnp.dot(t1.reshape(-1, 8), c.T)
    out = t2.reshape(-1, 8, 8).transpose(0, 2, 1)
    o_ref[...] = out.reshape(nb_i, nb_j, 8, 8).transpose(0, 2, 1, 3).reshape(rows, cols)


def dct8x8(x, basis=None):
    """x: f32[R, C] (R, C multiples of 8) -> blockwise 2D DCT."""
    if basis is None:
        basis = jnp.asarray(BASIS)
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=True,
    )(x, basis)
